//! Application of a [`LayerTransform`] to FFN weights (Eqns. 21–22):
//!
//! ```text
//!   W̄_up   = P · S · R · W_up        b̄_up = P · S · R · b_up
//!   W̄_down = W_down · Rᵀ · S⁻¹ · Pᵀ
//! ```
//!
//! Order matters: R innermost, then S, then P — matching the python-side
//! test helper (`python/tests/test_model.py::apply_ffn_transform`) so both
//! languages agree on the semantics.  P/S/R are never materialized as
//! matrices: rotation mixes row pairs, scaling multiplies rows/columns,
//! permutation gathers.

use super::state::LayerTransform;
use crate::model::Weights;
use crate::tensor::Tensor;

/// Transform `(W_up [f,d], b_up [1,f], W_down [d,f])`, returning new tensors.
pub fn apply_to_tensors(
    t: &LayerTransform,
    w_up: &Tensor,
    b_up: &Tensor,
    w_down: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let f = t.d_ffn();
    assert_eq!(w_up.rows, f, "W_up rows != d_ffn");
    assert_eq!(b_up.numel(), f, "b_up size != d_ffn");
    assert_eq!(w_down.cols, f, "W_down cols != d_ffn");

    let mut wu = w_up.clone();
    let mut bu = b_up.clone();
    let mut wd = w_down.clone();

    // R: rotate channel pairs (2p, 2p+1) by φ_p.  W_up rows / b_up entries
    // rotate forward; W_down columns rotate forward too (W_down·Rᵀ mixes
    // columns with the same angles).
    for (p, &phi) in t.phis.iter().enumerate() {
        if phi == 0.0 {
            continue;
        }
        let (i, j) = (2 * p, 2 * p + 1);
        let (c, s) = (phi.cos(), phi.sin());
        rotate_rows(&mut wu, i, j, c, s);
        let (bi, bj) = (bu.data[i], bu.data[j]);
        bu.data[i] = c * bi - s * bj;
        bu.data[j] = s * bi + c * bj;
        rotate_cols(&mut wd, i, j, c, s);
    }

    // S: scale channel i by s_i on the up side, 1/s_i on the down side.
    for (i, &s) in t.scale.iter().enumerate() {
        if s == 1.0 {
            continue;
        }
        wu.scale_row(i, s);
        bu.data[i] *= s;
        wd.scale_col(i, 1.0 / s);
    }

    // P: gather rows of W_up / entries of b_up / columns of W_down.
    if !t.perm.iter().enumerate().all(|(i, &p)| i == p) {
        wu = wu.gather_rows(&t.perm);
        let bu_new: Vec<f32> = t.perm.iter().map(|&p| bu.data[p]).collect();
        bu = Tensor::from_vec(1, f, bu_new);
        wd = wd.gather_cols(&t.perm);
    }

    (wu, bu, wd)
}

/// Rotate rows i, j of a tensor in place: `(ri, rj) <- (c·ri - s·rj, s·ri + c·rj)`.
fn rotate_rows(t: &mut Tensor, i: usize, j: usize, c: f32, s: f32) {
    let cols = t.cols;
    let (lo, hi) = if i < j { (i, j) } else { (j, i) };
    let (head, tail) = t.data.split_at_mut(hi * cols);
    let ri = &mut head[lo * cols..(lo + 1) * cols];
    let rj = &mut tail[..cols];
    for k in 0..cols {
        let (a, b) = (ri[k], rj[k]);
        ri[k] = c * a - s * b;
        rj[k] = s * a + c * b;
    }
}

/// Rotate columns i, j of a tensor in place.
fn rotate_cols(t: &mut Tensor, i: usize, j: usize, c: f32, s: f32) {
    for r in 0..t.rows {
        let base = r * t.cols;
        let (a, b) = (t.data[base + i], t.data[base + j]);
        t.data[base + i] = c * a - s * b;
        t.data[base + j] = s * a + c * b;
    }
}

/// Apply many `(layer, transform)` pairs to `base` concurrently, returning
/// the transformed `(W̄_up, b̄_up, W̄_down)` triple per request in order.
///
/// Borrow-friendly: `base` and the transforms are shared immutably across
/// the worker threads of [`crate::util::pool::parallel_map`] (scoped
/// threads, no `'static` bound), which is what lets the batched proposal
/// scheduler draft K candidates without cloning the weight set.
pub fn apply_batch(
    base: &Weights,
    reqs: &[(usize, &LayerTransform)],
) -> Vec<(Tensor, Tensor, Tensor)> {
    let threads = crate::util::pool::num_threads().min(reqs.len().max(1));
    crate::util::pool::parallel_map(reqs.len(), threads, |i| {
        let (l, t) = reqs[i];
        apply_to_tensors(
            t,
            base.layer(l, "up.w"),
            base.layer(l, "up.b"),
            base.layer(l, "down.w"),
        )
    })
}

/// Apply a transform to layer `l` of `base` (the untouched FP weights),
/// writing the transformed tensors into `out`.  `base` and `out` may be the
/// same model content-wise; `out` is overwritten at `l{l}.{up.w,up.b,down.w}`.
pub fn apply_to_layer(base: &Weights, out: &mut Weights, l: usize, t: &LayerTransform) {
    let (wu, bu, wd) = apply_to_tensors(
        t,
        base.layer(l, "up.w"),
        base.layer(l, "up.b"),
        base.layer(l, "down.w"),
    );
    out.set(&format!("l{l}.up.w"), wu);
    out.set(&format!("l{l}.up.b"), bu);
    out.set(&format!("l{l}.down.w"), wd);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::native::{forward, Capture};
    use crate::model::OptConfig;
    use crate::transform::TransformKinds;
    use crate::util::{propcheck, rng::Pcg64};

    fn rand_ffn(rng: &mut Pcg64, f: usize, d: usize) -> (Tensor, Tensor, Tensor) {
        let wu = Tensor::from_vec(f, d, (0..f * d).map(|_| rng.normal() as f32).collect());
        let bu = Tensor::from_vec(1, f, (0..f).map(|_| rng.normal() as f32).collect());
        let wd = Tensor::from_vec(d, f, (0..f * d).map(|_| rng.normal() as f32).collect());
        (wu, bu, wd)
    }

    /// Reference FFN: `W_down · relu(W_up·x + b_up)`.
    fn ffn_out(wu: &Tensor, bu: &Tensor, wd: &Tensor, x: &[f32]) -> Vec<f32> {
        let f = wu.rows;
        let mut u = vec![0.0f32; f];
        for i in 0..f {
            let mut s = bu.data[i];
            for (k, &xv) in x.iter().enumerate() {
                s += wu.at(i, k) * xv;
            }
            u[i] = s.max(0.0);
        }
        let d = wd.rows;
        let mut out = vec![0.0f32; d];
        for r in 0..d {
            let mut s = 0.0;
            for (i, &uv) in u.iter().enumerate() {
                s += wd.at(r, i) * uv;
            }
            out[r] = s;
        }
        out
    }

    #[test]
    fn permutation_scaling_exact_invariance() {
        propcheck::check("P,S leave FFN output unchanged", 24, |rng| {
            let (f, d) = (16, 8);
            let (wu, bu, wd) = rand_ffn(rng, f, d);
            let t = LayerTransform::identity(f).propose(
                rng,
                TransformKinds::parse("ps").unwrap(),
                0.5,
                0.2,
                0.0,
            );
            let (wu2, bu2, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y0 = ffn_out(&wu, &bu, &wd, &x);
            let y1 = ffn_out(&wu2, &bu2, &wd2, &x);
            propcheck::ensure_all_close(&y0, &y1, 1e-3, "FFN output")
        });
    }

    #[test]
    fn small_rotation_approx_invariance() {
        // §3.2 pilot: small angles change outputs only marginally.
        propcheck::check("small R approximately invariant", 16, |rng| {
            let (f, d) = (16, 8);
            let (wu, bu, wd) = rand_ffn(rng, f, d);
            let t = LayerTransform::identity(f).propose(
                rng,
                TransformKinds::parse("r").unwrap(),
                0.5,
                0.0,
                1e-4,
            );
            let (wu2, bu2, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y0 = ffn_out(&wu, &bu, &wd, &x);
            let y1 = ffn_out(&wu2, &bu2, &wd2, &x);
            let norm: f32 = y0.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            let diff: f32 = y0
                .iter()
                .zip(&y1)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            propcheck::ensure(diff / norm < 1e-2, format!("rel drift {}", diff / norm))
        });
    }

    #[test]
    fn large_rotation_breaks_invariance() {
        let mut rng = Pcg64::new(7);
        let (f, d) = (16, 8);
        let (wu, bu, wd) = rand_ffn(&mut rng, f, d);
        let mut t = LayerTransform::identity(f);
        for p in t.phis.iter_mut() {
            *p = 1.0; // ~57 degrees
        }
        let (wu2, bu2, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let y0 = ffn_out(&wu, &bu, &wd, &x);
        let y1 = ffn_out(&wu2, &bu2, &wd2, &x);
        let diff: f32 = y0.iter().zip(&y1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "large rotation should not be invariant");
    }

    #[test]
    fn rotation_orthogonality() {
        // R then R⁻¹ (negated angles, before any S/P) is the identity.
        let mut rng = Pcg64::new(8);
        let (f, d) = (8, 4);
        let (wu, bu, wd) = rand_ffn(&mut rng, f, d);
        let mut t = LayerTransform::identity(f);
        for p in t.phis.iter_mut() {
            *p = rng.normal() as f32 * 0.5;
        }
        let mut t_inv = LayerTransform::identity(f);
        for (a, b) in t_inv.phis.iter_mut().zip(&t.phis) {
            *a = -b;
        }
        let (wu1, bu1, wd1) = apply_to_tensors(&t, &wu, &bu, &wd);
        let (wu2, bu2, wd2) = apply_to_tensors(&t_inv, &wu1, &bu1, &wd1);
        propcheck::ensure_all_close(&wu.data, &wu2.data, 1e-5, "wu").unwrap();
        propcheck::ensure_all_close(&bu.data, &bu2.data, 1e-5, "bu").unwrap();
        propcheck::ensure_all_close(&wd.data, &wd2.data, 1e-5, "wd").unwrap();
    }

    #[test]
    fn full_model_invariance_via_native_forward() {
        // End-to-end: transformed full model has (nearly) identical CE.
        let cfg = OptConfig::test_config();
        let base = Weights::random(cfg.clone(), 10);
        let mut rng = Pcg64::new(11);
        let toks: Vec<Vec<i32>> = (0..2)
            .map(|_| (0..16).map(|_| rng.below(cfg.vocab) as i32).collect())
            .collect();
        let tgts: Vec<Vec<i32>> = toks
            .iter()
            .map(|s| {
                let mut x = s[1..].to_vec();
                x.push(s[0]);
                x
            })
            .collect();
        let mask = vec![vec![1.0; 16]; 2];
        let ce0 = forward(&base, &toks, &tgts, &mask, Capture::default()).ce;

        let mut w2 = base.clone();
        for l in 0..cfg.n_layers {
            let t = LayerTransform::identity(cfg.d_ffn).propose(
                &mut rng,
                TransformKinds::all(),
                0.3,
                0.1,
                1e-4,
            );
            apply_to_layer(&base, &mut w2, l, &t);
        }
        let ce1 = forward(&w2, &toks, &tgts, &mask, Capture::default()).ce;
        let drift = (ce1 - ce0).abs() / ce0;
        assert!(drift < 1e-3, "CE drift {drift} (ce0={ce0}, ce1={ce1})");
    }

    #[test]
    fn transform_changes_quant_error_distribution() {
        // The mechanism the paper exploits: FP-invariant but quant-variant.
        use crate::quant::{fake_quant, QuantScheme};
        let mut rng = Pcg64::new(12);
        let (f, d) = (32, 64);
        let (wu, bu, wd) = rand_ffn(&mut rng, f, d);
        let scheme = QuantScheme::new(2, 32);
        let e0 = wd.mse(&fake_quant(&wd, scheme));
        let t = LayerTransform::identity(f).propose(
            &mut rng,
            TransformKinds::parse("s").unwrap(),
            0.5,
            0.5,
            0.0,
        );
        let (_, _, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
        let e1 = wd2.mse(&fake_quant(&wd2, scheme));
        assert!((e0 - e1).abs() / e0 > 1e-4, "quant error unchanged: {e0} vs {e1}");
    }

    #[test]
    fn apply_batch_matches_sequential_application() {
        let cfg = OptConfig::test_config();
        let base = Weights::random(cfg.clone(), 21);
        let mut rng = Pcg64::new(22);
        let transforms: Vec<LayerTransform> = (0..cfg.n_layers)
            .map(|_| {
                LayerTransform::identity(cfg.d_ffn).propose(
                    &mut rng,
                    TransformKinds::all(),
                    0.3,
                    0.1,
                    1e-3,
                )
            })
            .collect();
        let reqs: Vec<(usize, &LayerTransform)> =
            transforms.iter().enumerate().collect();
        let batch = apply_batch(&base, &reqs);
        assert_eq!(batch.len(), cfg.n_layers);
        for (l, t) in transforms.iter().enumerate() {
            let (wu, bu, wd) = apply_to_tensors(
                t,
                base.layer(l, "up.w"),
                base.layer(l, "up.b"),
                base.layer(l, "down.w"),
            );
            assert_eq!(batch[l].0, wu, "layer {l} W_up mismatch");
            assert_eq!(batch[l].1, bu, "layer {l} b_up mismatch");
            assert_eq!(batch[l].2, wd, "layer {l} W_down mismatch");
        }
    }

    #[test]
    fn identity_transform_is_noop() {
        let mut rng = Pcg64::new(13);
        let (wu, bu, wd) = rand_ffn(&mut rng, 8, 4);
        let t = LayerTransform::identity(8);
        let (wu2, bu2, wd2) = apply_to_tensors(&t, &wu, &bu, &wd);
        assert_eq!(wu, wu2);
        assert_eq!(bu, bu2);
        assert_eq!(wd, wd2);
    }
}

//! Transform state (π, s, φ per layer) and proposal sampling (Algorithm 1,
//! lines 12–14).

use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Which transform families the search may use (Table-2 ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransformKinds {
    pub permutation: bool,
    pub scaling: bool,
    pub rotation: bool,
}

impl TransformKinds {
    pub fn all() -> Self {
        TransformKinds { permutation: true, scaling: true, rotation: true }
    }

    pub fn none() -> Self {
        TransformKinds { permutation: false, scaling: false, rotation: false }
    }

    /// Parse CLI strings like "psr", "p", "sr".
    pub fn parse(s: &str) -> crate::Result<Self> {
        let mut k = Self::none();
        for c in s.chars() {
            match c {
                'p' => k.permutation = true,
                's' => k.scaling = true,
                'r' => k.rotation = true,
                _ => anyhow::bail!("unknown transform kind {c:?} (want subset of \"psr\")"),
            }
        }
        Ok(k)
    }

    pub fn label(&self) -> String {
        let mut s = String::new();
        if self.permutation {
            s.push('P');
        }
        if self.scaling {
            s.push('S');
        }
        if self.rotation {
            s.push('R');
        }
        if s.is_empty() {
            s.push('-');
        }
        s
    }
}

/// The invariant transform of one FFN block: `W̄_up = P·S·R·W_up`,
/// `W̄_down = W_down·Rᵀ·S⁻¹·Pᵀ` (Eqns. 21–22).
///
/// * `perm[i]` = source index feeding output slot `i` (so `perm = identity`
///   means no permutation);
/// * `scale[i]` = multiplicative factor for FFN channel `i` (must be > 0
///   for ReLU invariance);
/// * `phis[p]` = rotation angle of the channel pair `(2p, 2p+1)`.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTransform {
    pub perm: Vec<usize>,
    pub scale: Vec<f32>,
    pub phis: Vec<f32>,
}

impl LayerTransform {
    pub fn identity(d_ffn: usize) -> LayerTransform {
        assert!(d_ffn % 2 == 0, "d_ffn must be even for pairwise rotation");
        LayerTransform {
            perm: (0..d_ffn).collect(),
            scale: vec![1.0; d_ffn],
            phis: vec![0.0; d_ffn / 2],
        }
    }

    pub fn d_ffn(&self) -> usize {
        self.perm.len()
    }

    pub fn is_identity(&self) -> bool {
        self.perm.iter().enumerate().all(|(i, &p)| i == p)
            && self.scale.iter().all(|&s| s == 1.0)
            && self.phis.iter().all(|&p| p == 0.0)
    }

    /// Sample a proposal around this state (Algorithm 1 lines 12–14, plus
    /// the §3.2 detail that only a `frac` subset of channels moves per step).
    ///
    /// * permutation: re-shuffle a random subset of `frac·d` slots;
    /// * scaling: Gaussian random walk `s' ~ N(s, σ_s²)` on a subset
    ///   (clamped positive — ReLU invariance needs s > 0);
    /// * rotation: random walk `φ' ~ N(φ, σ_r²)` on a subset of pairs.
    pub fn propose(
        &self,
        rng: &mut Pcg64,
        kinds: TransformKinds,
        frac: f64,
        sigma_s: f64,
        sigma_r: f64,
    ) -> LayerTransform {
        let d = self.d_ffn();
        let k = ((d as f64 * frac).round() as usize).clamp(2, d);
        let mut next = self.clone();

        if kinds.permutation {
            // shuffle the *composition*: pick k slots and cycle their sources
            let slots = rng.sample_indices(d, k);
            let mut srcs: Vec<usize> = slots.iter().map(|&i| next.perm[i]).collect();
            rng.shuffle(&mut srcs);
            for (slot, src) in slots.iter().zip(srcs) {
                next.perm[*slot] = src;
            }
        }
        if kinds.scaling {
            for &i in &rng.sample_indices(d, k) {
                let s = rng.normal_with(next.scale[i] as f64, sigma_s) as f32;
                next.scale[i] = s.max(1e-3); // keep positive (ReLU identity)
            }
        }
        if kinds.rotation {
            let pairs = d / 2;
            let kp = (k / 2).max(1);
            for &p in &rng.sample_indices(pairs, kp) {
                next.phis[p] = rng.normal_with(next.phis[p] as f64, sigma_r) as f32;
            }
        }
        next
    }

    /// Validity: perm is a bijection, scales positive, sizes consistent.
    pub fn validate(&self) -> crate::Result<()> {
        let d = self.d_ffn();
        anyhow::ensure!(self.scale.len() == d, "scale length mismatch");
        anyhow::ensure!(self.phis.len() == d / 2, "phis length mismatch");
        let mut seen = vec![false; d];
        for &p in &self.perm {
            anyhow::ensure!(p < d, "perm index {p} out of range");
            anyhow::ensure!(!seen[p], "perm not a bijection (dup {p})");
            seen[p] = true;
        }
        anyhow::ensure!(
            self.scale.iter().all(|&s| s > 0.0 && s.is_finite()),
            "scales must be positive finite"
        );
        anyhow::ensure!(self.phis.iter().all(|p| p.is_finite()), "phis must be finite");
        Ok(())
    }

    // -- (de)serialization for search-state checkpoints ----------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("perm", self.perm.iter().map(|&p| Json::from(p)).collect::<Vec<_>>())
            .set("scale", self.scale.iter().map(|&s| Json::from(s as f64)).collect::<Vec<_>>())
            .set("phis", self.phis.iter().map(|&p| Json::from(p as f64)).collect::<Vec<_>>())
    }

    pub fn from_json(j: &Json) -> crate::Result<LayerTransform> {
        let t = LayerTransform {
            perm: j.req("perm")?.usize_array()?,
            scale: j.req("scale")?.f64_array()?.into_iter().map(|v| v as f32).collect(),
            phis: j.req("phis")?.f64_array()?.into_iter().map(|v| v as f32).collect(),
        };
        t.validate()?;
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn identity_is_identity() {
        let t = LayerTransform::identity(64);
        assert!(t.is_identity());
        t.validate().unwrap();
    }

    #[test]
    fn propose_stays_valid() {
        propcheck::check("proposals remain valid transforms", 64, |rng| {
            let mut t = LayerTransform::identity(32);
            for _ in 0..10 {
                t = t.propose(rng, TransformKinds::all(), 0.1, 1e-2, 1e-5);
                t.validate().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn propose_respects_kinds() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let t0 = LayerTransform::identity(32);
        let p_only = t0.propose(&mut rng, TransformKinds::parse("p").unwrap(), 0.2, 1e-2, 1e-5);
        assert!(p_only.scale.iter().all(|&s| s == 1.0));
        assert!(p_only.phis.iter().all(|&p| p == 0.0));
        assert!(!p_only.perm.iter().enumerate().all(|(i, &p)| i == p));

        let s_only = t0.propose(&mut rng, TransformKinds::parse("s").unwrap(), 0.2, 1e-1, 1e-5);
        assert!(s_only.perm.iter().enumerate().all(|(i, &p)| i == p));
        assert!(s_only.scale.iter().any(|&s| s != 1.0));
    }

    #[test]
    fn proposal_changes_bounded_subset() {
        let mut rng = crate::util::rng::Pcg64::new(2);
        let t0 = LayerTransform::identity(100);
        let t1 = t0.propose(&mut rng, TransformKinds::parse("s").unwrap(), 0.1, 1e-2, 1e-5);
        let changed = t1.scale.iter().filter(|&&s| s != 1.0).count();
        assert!(changed <= 10, "changed {changed}");
    }

    #[test]
    fn scales_stay_positive() {
        propcheck::check("scale positivity under huge sigma", 32, |rng| {
            let mut t = LayerTransform::identity(16);
            for _ in 0..20 {
                t = t.propose(rng, TransformKinds::all(), 0.5, 10.0, 0.1);
            }
            propcheck::ensure(t.scale.iter().all(|&s| s > 0.0), "nonpositive scale")
        });
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = crate::util::rng::Pcg64::new(3);
        let t = LayerTransform::identity(16).propose(&mut rng, TransformKinds::all(), 0.3, 0.05, 1e-4);
        let back = LayerTransform::from_json(&t.to_json()).unwrap();
        assert_eq!(t.perm, back.perm);
        for (a, b) in t.scale.iter().zip(&back.scale) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(TransformKinds::parse("psr").unwrap(), TransformKinds::all());
        let p = TransformKinds::parse("p").unwrap();
        assert!(p.permutation && !p.scaling && !p.rotation);
        assert!(TransformKinds::parse("x").is_err());
        assert_eq!(TransformKinds::all().label(), "PSR");
        assert_eq!(TransformKinds::none().label(), "-");
    }

    #[test]
    fn invalid_transforms_rejected() {
        let mut t = LayerTransform::identity(8);
        t.perm[0] = 1;
        t.perm[1] = 1;
        assert!(t.validate().is_err());
        let mut t2 = LayerTransform::identity(8);
        t2.scale[3] = -1.0;
        assert!(t2.validate().is_err());
        let mut t3 = LayerTransform::identity(8);
        t3.phis[0] = f32::NAN;
        assert!(t3.validate().is_err());
    }
}

//! Invariant transformations of the FFN block (paper §3.2, Eqns. 8–22).
//!
//! A transform is stored as vectors — a permutation π, a scale vector s and
//! rotation angles φ — never as matrices; application is indexing and
//! elementwise math (the paper makes the same point under Eqn. 11).

pub mod apply;
pub mod state;

pub use apply::{apply_batch, apply_to_layer, apply_to_tensors};
pub use state::{LayerTransform, TransformKinds};

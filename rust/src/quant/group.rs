//! The groupwise asymmetric quantization codec (Eqns. 1–4).
//!
//! MUST stay bit-compatible with `python/compile/kernels/ref.py` and the
//! Pallas kernel: q_min = 0, `s = (max-min)/qmax` with degenerate-group
//! fallback `s = 1` (a constant group dequantizes to `round(c)` saturated
//! into `[-qmax, qmax]`), round-half-up (`floor(x + 0.5)`), and the
//! zero-point clamped into `[0, qmax]` so it always fits the packed
//! integer width
//! (`quant::packed` stores zeros in `bits` bits — an unclamped zero from a
//! single-sign group would saturate or truncate there and silently corrupt
//! the deployment form).  A cross-layer test
//! (`rust/tests/hlo_cross_check.rs`) pins all three implementations
//! together.

use super::QuantScheme;
use crate::tensor::Tensor;

/// Quantized representation of one `[rows, cols]` weight matrix:
/// integer codes (u8, one per weight — packing into words is
/// [`super::packed`]'s job) + per-group scale/zero.
#[derive(Debug, Clone)]
pub struct GroupQuant {
    /// Bits + group size the matrix was quantized under.
    pub scheme: QuantScheme,
    /// Rows of the source matrix.
    pub rows: usize,
    /// Columns of the source matrix (`cols % scheme.group == 0`).
    pub cols: usize,
    /// `[rows * cols]` integer codes in `[0, qmax]`, one byte per weight.
    pub codes: Vec<u8>,
    /// `[rows * cols/group]` FP scales.
    pub scales: Vec<f32>,
    /// `[rows * cols/group]` integer zero points (stored as f32 to mirror
    /// the reference; values are integral).
    pub zeros: Vec<f32>,
}

#[inline]
fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// Quantize a weight matrix; `cols % group == 0` required.
pub fn quantize(w: &Tensor, scheme: QuantScheme) -> GroupQuant {
    let (rows, cols) = w.shape();
    assert_eq!(
        cols % scheme.group,
        0,
        "cols={cols} not divisible by group={}",
        scheme.group
    );
    let qmax = scheme.qmax();
    let n_groups = cols / scheme.group;
    let mut codes = vec![0u8; rows * cols];
    let mut scales = vec![0f32; rows * n_groups];
    let mut zeros = vec![0f32; rows * n_groups];

    for r in 0..rows {
        let row = w.row(r);
        for g in 0..n_groups {
            let seg = &row[g * scheme.group..(g + 1) * scheme.group];
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &v in seg {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let range = mx - mn;
            let scale = if range > 0.0 { range / qmax } else { 1.0 };
            // clamp: all-positive groups would otherwise yield zero < 0 and
            // all-negative groups zero > qmax, neither of which survives the
            // bit-packed storage (see module doc).  Deliberate trade-off:
            // a clamped single-sign group loses the s/2 error bound (its
            // representable range is pinned at 0) — the alternative of
            // widening [mn, mx] to include 0 would keep s/2 but change the
            // paper's s = (max-min)/qmax scale definition everywhere.
            // Near-zero-mean LLM weight groups are unaffected.
            let zero = round_half_up(-mn / scale).clamp(0.0, qmax);
            scales[r * n_groups + g] = scale;
            zeros[r * n_groups + g] = zero;
            let dst = &mut codes[r * cols + g * scheme.group..r * cols + (g + 1) * scheme.group];
            for (d, &v) in dst.iter_mut().zip(seg) {
                let q = round_half_up(v / scale) + zero;
                *d = q.clamp(0.0, qmax) as u8;
            }
        }
    }
    GroupQuant {
        scheme,
        rows,
        cols,
        codes,
        scales,
        zeros,
    }
}

/// Dequantize back to a dense tensor (Eqn. 4).
pub fn dequantize(q: &GroupQuant) -> Tensor {
    let n_groups = q.cols / q.scheme.group;
    let mut out = Tensor::zeros(q.rows, q.cols);
    for r in 0..q.rows {
        for g in 0..n_groups {
            let scale = q.scales[r * n_groups + g];
            let zero = q.zeros[r * n_groups + g];
            let base = r * q.cols + g * q.scheme.group;
            for i in 0..q.scheme.group {
                out.data[base + i] = scale * (q.codes[base + i] as f32 - zero);
            }
        }
    }
    out
}

/// quant→dequant roundtrip ("fake quantization" — what the search loop
/// evaluates).  Allocation-free variant: [`fake_quant_into`].
pub fn fake_quant(w: &Tensor, scheme: QuantScheme) -> Tensor {
    let mut out = Tensor::zeros(w.rows, w.cols);
    fake_quant_into(w, scheme, &mut out);
    out
}

/// Fake-quantize `w` into a preallocated `out` without materializing codes
/// — the hot-path version used per search proposal.
pub fn fake_quant_into(w: &Tensor, scheme: QuantScheme, out: &mut Tensor) {
    let (rows, cols) = w.shape();
    assert_eq!(out.shape(), (rows, cols));
    assert_eq!(cols % scheme.group, 0);
    let qmax = scheme.qmax();
    for r in 0..rows {
        let row = w.row(r);
        let orow = out.row_mut(r);
        for g in 0..cols / scheme.group {
            let a = g * scheme.group;
            let seg = &row[a..a + scheme.group];
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &v in seg {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let range = mx - mn;
            let scale = if range > 0.0 { range / qmax } else { 1.0 };
            let zero = round_half_up(-mn / scale).clamp(0.0, qmax);
            for (o, &v) in orow[a..a + scheme.group].iter_mut().zip(seg) {
                let q = (round_half_up(v / scale) + zero).clamp(0.0, qmax);
                *o = scale * (q - zero);
            }
        }
    }
}

/// Mean-squared quantization error of a matrix under a scheme — the metric
/// AWQ's grid searches minimize.
pub fn quant_mse(w: &Tensor, scheme: QuantScheme) -> f64 {
    let deq = fake_quant(w, scheme);
    w.mse(&deq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, rng::Pcg64};

    fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize, scale: f32) -> Tensor {
        Tensor::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect(),
        )
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        propcheck::check("‖w - deq‖∞ ≤ s/2", 48, |rng| {
            let scheme = QuantScheme::new(rng.below(4) + 1, *rng.choice(&[16usize, 32, 64]));
            let rows = rng.below(6) + 1;
            let cols = scheme.group * (rng.below(3) + 1);
            let w = rand_tensor(rng, rows, cols, 1.0);
            let q = quantize(&w, scheme);
            let deq = dequantize(&q);
            let n_groups = cols / scheme.group;
            for r in 0..rows {
                for c in 0..cols {
                    let g = c / scheme.group;
                    let seg = &w.row(r)[g * scheme.group..(g + 1) * scheme.group];
                    let mn = seg.iter().fold(f32::INFINITY, |m, &v| m.min(v));
                    let mx = seg.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                    let s = q.scales[r * n_groups + g];
                    // zero-spanning groups keep the classic s/2 bound; a
                    // single-sign group additionally pays for the zero-point
                    // clamp (its representable range is pinned at 0)
                    let bound = if mn <= 0.0 && mx >= 0.0 {
                        s * 0.5 + 1e-5
                    } else {
                        mn.abs().min(mx.abs()) + s * 0.5 + 1e-5
                    };
                    let err = (w.at(r, c) - deq.at(r, c)).abs();
                    if err > bound {
                        return Err(format!("err {err} > bound {bound} at ({r},{c})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_point_always_packable() {
        // REGRESSION (PR 2): single-sign groups used to produce zero-points
        // outside [0, qmax], which corrupted the bit-packed deployment form.
        propcheck::check("zero ∈ [0, qmax] under shifted distributions", 48, |rng| {
            let scheme = QuantScheme::new(rng.below(8) + 1, 32);
            let shift = *rng.choice(&[-4.0f32, -1.0, 0.0, 1.0, 4.0]);
            let w = Tensor::from_vec(
                2,
                64,
                (0..128).map(|_| rng.normal() as f32 * 0.5 + shift).collect(),
            );
            let q = quantize(&w, scheme);
            let qmax = scheme.qmax();
            for &z in &q.zeros {
                if !(0.0..=qmax).contains(&z) || z != z.floor() {
                    return Err(format!("zero {z} not an integer in [0, {qmax}]"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fake_quant_equals_quant_dequant() {
        propcheck::check("fake_quant == dequant(quantize)", 32, |rng| {
            let scheme = QuantScheme::new(rng.below(3) + 1, 32);
            let w = rand_tensor(rng, 4, 64, 2.0);
            let a = fake_quant(&w, scheme);
            let b = dequantize(&quantize(&w, scheme));
            propcheck::ensure_all_close(&a.data, &b.data, 0.0, "fake_quant")
        });
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Pcg64::new(1);
        for bits in 1..=4 {
            let scheme = QuantScheme::new(bits, 32);
            let w = rand_tensor(&mut rng, 8, 64, 3.0);
            let q = quantize(&w, scheme);
            assert!(q.codes.iter().all(|&c| c <= scheme.qmax() as u8));
        }
    }

    #[test]
    fn extremes_are_representable() {
        // max and min of each group must quantize with ~zero error
        let mut rng = Pcg64::new(2);
        let scheme = QuantScheme::new(2, 32);
        let w = rand_tensor(&mut rng, 4, 64, 1.0);
        let deq = fake_quant(&w, scheme);
        for r in 0..4 {
            for g in 0..2 {
                let seg: Vec<f32> = w.row(r)[g * 32..(g + 1) * 32].to_vec();
                let dseg: Vec<f32> = deq.row(r)[g * 32..(g + 1) * 32].to_vec();
                let (mut mni, mut mxi) = (0, 0);
                for (i, &v) in seg.iter().enumerate() {
                    if v < seg[mni] {
                        mni = i;
                    }
                    if v > seg[mxi] {
                        mxi = i;
                    }
                }
                let s = (seg[mxi] - seg[mni]) / 3.0;
                assert!((dseg[mxi] - seg[mxi]).abs() <= s * 0.51 + 1e-6);
                assert!((dseg[mni] - seg[mni]).abs() <= s * 0.51 + 1e-6);
            }
        }
    }

    #[test]
    fn degenerate_constant_group() {
        let w = Tensor::from_vec(1, 32, vec![3.2; 32]);
        let deq = fake_quant(&w, QuantScheme::new(2, 32));
        // degenerate fallback: s=1 -> dequantizes to round(3.2) = 3
        assert!(deq.data.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn degenerate_far_constant_saturates() {
        // post-clamp semantics (module doc): a constant group with
        // |c| > qmax saturates to ±qmax instead of reaching round(c)
        let scheme = QuantScheme::new(2, 32);
        let hi = fake_quant(&Tensor::from_vec(1, 32, vec![10.0; 32]), scheme);
        assert!(hi.data.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        let lo = fake_quant(&Tensor::from_vec(1, 32, vec![-10.0; 32]), scheme);
        assert!(lo.data.iter().all(|&v| (v + 3.0).abs() < 1e-6));
    }

    #[test]
    fn idempotent() {
        let mut rng = Pcg64::new(3);
        let scheme = QuantScheme::new(2, 32);
        let w = rand_tensor(&mut rng, 4, 64, 1.0);
        let d1 = fake_quant(&w, scheme);
        let d2 = fake_quant(&d1, scheme);
        for (a, b) in d1.data.iter().zip(&d2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn outlier_inflates_group_error() {
        // the paper's core motivation: an outlier blows up s for its group
        let mut rng = Pcg64::new(4);
        let scheme = QuantScheme::new(2, 32);
        let mut w = rand_tensor(&mut rng, 1, 64, 0.1);
        let base_err = quant_mse(&w, scheme);
        w.data[5] = 50.0; // outlier in group 0
        let q = quantize(&w, scheme);
        assert!(q.scales[0] > 10.0 * q.scales[1]);
        // the non-outlier weights of group 0 collapse to the zero-point, so
        // their error ~ their own magnitude — a clear multiple of base MSE
        assert!(quant_mse(&w, scheme) > base_err * 2.0);
    }

    #[test]
    fn into_variant_matches() {
        let mut rng = Pcg64::new(5);
        let scheme = QuantScheme::new(3, 32);
        let w = rand_tensor(&mut rng, 8, 96, 1.0);
        let a = fake_quant(&w, scheme);
        let mut b = Tensor::zeros(8, 96);
        fake_quant_into(&w, scheme, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn mse_decreases_with_bits() {
        let mut rng = Pcg64::new(6);
        let w = rand_tensor(&mut rng, 16, 128, 1.0);
        let errs: Vec<f64> = (1..=8)
            .map(|b| quant_mse(&w, QuantScheme::new(b, 64)))
            .collect();
        for win in errs.windows(2) {
            assert!(win[0] >= win[1]);
        }
    }
}

//! Bit-packed storage of quantized codes — the *actual* memory layout a
//! deployment would ship, used to compute the honest "Bits/Param" and
//! memory-savings columns of Table 3 and by the serve example to hold the
//! model compressed in RAM.
//!
//! Codes are packed little-endian, `bits` each, into u32 words, rows padded
//! to word boundaries so rows stay independently addressable.  Scales are
//! stored as f16 bit patterns (matching the paper's FP16 scale accounting)
//! and zero-points as packed ints.

use super::{GroupQuant, QuantScheme};
use crate::tensor::Tensor;

/// A weight matrix in deployment form.
#[derive(Debug, Clone)]
pub struct PackedTensor {
    pub scheme: QuantScheme,
    pub rows: usize,
    pub cols: usize,
    /// Packed codes, `words_per_row` u32 per row.
    pub words: Vec<u32>,
    pub words_per_row: usize,
    /// f16 bit patterns of per-group scales.
    pub scales_f16: Vec<u16>,
    /// Packed zero-points (same bit width as codes).
    pub zero_words: Vec<u32>,
}

/// Lossy f32 -> f16 (round-to-nearest, ties away from zero).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let sign: u16 = if x.is_sign_negative() { 0x8000 } else { 0 };
    let ax = x.abs();
    if ax.is_nan() {
        return sign | 0x7e00;
    }
    if ax == 0.0 {
        return sign;
    }
    let e = ((ax.to_bits() >> 23) & 0xff) as i32 - 127;
    if e < -14 {
        // subnormal target: units of 2^-24
        let n = (ax * (1u32 << 24) as f32).round() as u32;
        if n >= 1024 {
            return sign | 0x0400; // rounds up into the smallest normal
        }
        return sign | n as u16;
    }
    // normal: mantissa in [1024, 2048) units of 2^(e-10)
    let mant = (ax * 2f32.powi(10 - e)).round() as u32;
    let (mant, e) = if mant >= 2048 { (1024, e + 1) } else { (mant, e) };
    if e > 15 {
        return sign | 0x7c00; // inf/overflow
    }
    sign | (((e + 15) as u16) << 10) | ((mant - 1024) as u16)
}

/// f16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac · 2⁻²⁴; normalize so bit 10 is set
            // after k shifts the f32 exponent field is 113 - k
            let mut e: i32 = 102; // 113 - 11; decremented once per shift
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            sign | (((e + 11) as u32) << 23) | (f << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

fn pack_values(values: impl Iterator<Item = u8>, bits: usize) -> Vec<u32> {
    let mut words = Vec::new();
    let mut cur = 0u32;
    let mut used = 0usize;
    for v in values {
        debug_assert!((v as u32) < (1 << bits));
        cur |= (v as u32) << used;
        used += bits;
        if used + bits > 32 {
            words.push(cur);
            cur = 0;
            used = 0;
        }
    }
    if used > 0 {
        words.push(cur);
    }
    words
}

fn unpack_value(words: &[u32], bits: usize, index: usize) -> u8 {
    let per_word = 32 / bits;
    let w = words[index / per_word];
    ((w >> ((index % per_word) * bits)) & ((1 << bits) - 1)) as u8
}

impl PackedTensor {
    /// Pack a [`GroupQuant`].
    pub fn pack(q: &GroupQuant) -> PackedTensor {
        let bits = q.scheme.bits;
        let per_word = 32 / bits;
        let words_per_row = q.cols.div_ceil(per_word);
        let mut words = Vec::with_capacity(q.rows * words_per_row);
        for r in 0..q.rows {
            let row_words = pack_values(
                q.codes[r * q.cols..(r + 1) * q.cols].iter().copied(),
                bits,
            );
            debug_assert!(row_words.len() <= words_per_row);
            words.extend(&row_words);
            words.extend(std::iter::repeat(0).take(words_per_row - row_words.len()));
        }
        let scales_f16 = q.scales.iter().map(|&s| f32_to_f16_bits(s)).collect();
        let zero_words = pack_values(q.zeros.iter().map(|&z| z as u8), bits.max(1));
        PackedTensor {
            scheme: q.scheme,
            rows: q.rows,
            cols: q.cols,
            words,
            words_per_row,
            scales_f16,
            zero_words,
        }
    }

    /// Unpack back to dense dequantized weights (f16 scale precision —
    /// this is the deployment-faithful dequant).
    pub fn unpack(&self) -> Tensor {
        let bits = self.scheme.bits;
        let per_word = 32 / bits;
        let n_groups = self.cols / self.scheme.group;
        let mut out = Tensor::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row_words = &self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
            for c in 0..self.cols {
                let code = ((row_words[c / per_word] >> ((c % per_word) * bits))
                    & ((1 << bits) - 1)) as f32;
                let g = r * n_groups + c / self.scheme.group;
                let scale = f16_bits_to_f32(self.scales_f16[g]);
                let zero = unpack_value(&self.zero_words, bits, g) as f32;
                out.data[r * self.cols + c] = scale * (code - zero);
            }
        }
        out
    }

    /// Total storage in bytes (codes + scales + zeros).
    pub fn nbytes(&self) -> usize {
        self.words.len() * 4 + self.scales_f16.len() * 2 + self.zero_words.len() * 4
    }

    /// Measured bits per parameter — the honest Table-3 column.
    pub fn bits_per_param(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::quantize;
    use crate::util::{propcheck, rng::Pcg64};

    #[test]
    fn f16_roundtrip_exactish() {
        for &x in &[0.0f32, 1.0, -2.5, 0.333, 1e-3, 65504.0, -1e-6] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let tol = (x.abs() * 1e-3).max(1e-7);
            assert!((x - y).abs() <= tol, "{x} -> {y}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e30)).is_infinite());
    }

    #[test]
    fn pack_unpack_preserves_codes() {
        propcheck::check("pack/unpack code fidelity", 24, |rng| {
            let bits = rng.below(4) + 1;
            let scheme = QuantScheme::new(bits, 32);
            let rows = rng.below(5) + 1;
            let cols = 32 * (rng.below(3) + 1);
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32).collect(),
            );
            let q = quantize(&w, scheme);
            let packed = PackedTensor::pack(&q);
            let unpacked = packed.unpack();
            // unpack differs from exact dequant only by f16 scale rounding
            let exact = crate::quant::group::dequantize(&q);
            for (a, b) in exact.data.iter().zip(&unpacked.data) {
                let tol = (a.abs() * 2e-3).max(1e-4);
                if (a - b).abs() > tol {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bits_per_param_close_to_nominal() {
        let mut rng = Pcg64::new(1);
        let scheme = QuantScheme::new(2, 64);
        let w = Tensor::from_vec(
            64,
            1024,
            (0..64 * 1024).map(|_| rng.normal() as f32).collect(),
        );
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        let bpp = packed.bits_per_param();
        // 2 bits + 16/64 scale + 2/64 zero ≈ 2.28, plus padding slack
        assert!(bpp > 2.0 && bpp < 2.6, "bpp {bpp}");
        // memory saving vs f32 ≥ 85% (paper's claim vs FP16 is 85% at 2.125)
        let savings = 1.0 - packed.nbytes() as f64 / (64.0 * 1024.0 * 2.0); // vs f16
        assert!(savings > 0.8, "savings {savings}");
    }

    #[test]
    fn words_per_row_padding() {
        // cols=96, bits=3 -> per_word=10 -> 10 words/row (96/10 = 9.6)
        let scheme = QuantScheme::new(3, 32);
        let w = Tensor::zeros(2, 96);
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        assert_eq!(packed.words_per_row, 10);
        assert_eq!(packed.words.len(), 20);
    }
}

//! Bit-packed storage of quantized codes — the *actual* memory layout a
//! deployment would ship, used to compute the honest "Bits/Param" and
//! memory-savings columns of Table 3 and, since PR 2, to *serve* the model
//! directly: the fused unpack→dequant→GEMM kernels here run the decoder
//! forward on the packed codes without ever materializing a dense f32 copy
//! of a quantized linear (see [`crate::serve`]).
//!
//! Codes are packed little-endian, `bits` each, into u32 words, rows padded
//! to word boundaries so rows stay independently addressable.  Scales are
//! stored as f16 bit patterns (matching the paper's FP16 scale accounting)
//! and zero-points as packed ints.

use super::{simd, GroupQuant, QuantScheme};
use crate::tensor::{ops, Tensor};
use crate::util::pool;

/// Output-row tile of the fused GEMM kernels.  MUST stay a multiple of 4:
/// [`ops::matmul_nt`] switches from its 4-wide j-blocked inner kernel to a
/// per-column `dot` tail based on column alignment, and a multiple-of-4
/// tile keeps that classification identical between a whole-matrix call
/// and the tiled calls — which is what makes [`PackedTensor::linear`]
/// bit-identical to `ops::linear` over [`PackedTensor::unpack`] (pinned by
/// `fused_linear_bit_identical_to_unpack`).
const ROW_TILE: usize = 64;

/// A weight matrix in deployment form.
#[derive(Debug, Clone)]
pub struct PackedTensor {
    /// Quantization scheme (bit width + group size) of every code.
    pub scheme: QuantScheme,
    /// Output rows of the weight matrix.
    pub rows: usize,
    /// Input columns of the weight matrix.
    pub cols: usize,
    /// Packed codes, `words_per_row` u32 per row.
    pub words: Vec<u32>,
    /// u32 words holding each row's codes (rows are padded to word
    /// boundaries so they stay independently addressable).
    pub words_per_row: usize,
    /// f16 bit patterns of per-group scales.
    pub scales_f16: Vec<u16>,
    /// Packed zero-points (same bit width as codes).
    pub zero_words: Vec<u32>,
}

/// Lossy f32 -> f16 (round-to-nearest, **ties away from zero**).
///
/// Rounding choice, documented deliberately: Rust's `f32::round` resolves a
/// value exactly halfway between two representable f16 mantissas toward the
/// larger magnitude, unlike IEEE-754's default round-to-nearest-even.  This
/// matches the `floor(x + 0.5)` round-half-up convention the quantization
/// codec uses on non-negative inputs (`quant::group`), keeps the packer
/// dependency-free, and differs from ties-to-even only on exact midpoints
/// (≤ 1 ulp, i.e. within the scale-precision tolerance every packed-dequant
/// test already budgets for).  Every value that IS exactly representable in
/// f16 round-trips bit-exactly — pinned over all 65536 bit patterns by
/// `f16_u16_exhaustive_roundtrip`.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let sign: u16 = if x.is_sign_negative() { 0x8000 } else { 0 };
    let ax = x.abs();
    if ax.is_nan() {
        return sign | 0x7e00;
    }
    if ax == 0.0 {
        return sign;
    }
    let e = ((ax.to_bits() >> 23) & 0xff) as i32 - 127;
    if e < -14 {
        // subnormal target: units of 2^-24
        let n = (ax * (1u32 << 24) as f32).round() as u32;
        if n >= 1024 {
            return sign | 0x0400; // rounds up into the smallest normal
        }
        // CLAMPED: n < 1024 (checked above), so it fits the 10-bit field.
        return sign | n as u16;
    }
    // normal: mantissa in [1024, 2048) units of 2^(e-10)
    let mant = (ax * 2f32.powi(10 - e)).round() as u32;
    let (mant, e) = if mant >= 2048 { (1024, e + 1) } else { (mant, e) };
    if e > 15 {
        return sign | 0x7c00; // inf/overflow
    }
    // CLAMPED: e in [-14, 15] here so e+15 in [1, 30] (5-bit field); mant
    // in [1024, 2048) so mant-1024 in [0, 1024) (10-bit field).
    sign | (((e + 15) as u16) << 10) | ((mant - 1024) as u16)
}

/// f16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: value = frac · 2⁻²⁴; normalize so bit 10 is set
            // after k shifts the f32 exponent field is 113 - k
            let mut e: i32 = 102; // 113 - 11; decremented once per shift
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x3ff;
            sign | (((e + 11) as u32) << 23) | (f << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

fn pack_values(values: impl Iterator<Item = u8>, bits: usize) -> Vec<u32> {
    let mut words = Vec::new();
    let mut cur = 0u32;
    let mut used = 0usize;
    for v in values {
        debug_assert!((v as u32) < (1 << bits));
        cur |= (v as u32) << used;
        used += bits;
        if used + bits > 32 {
            words.push(cur);
            cur = 0;
            used = 0;
        }
    }
    if used > 0 {
        words.push(cur);
    }
    words
}

fn unpack_value(words: &[u32], bits: usize, index: usize) -> u8 {
    let per_word = 32 / bits;
    let w = words[index / per_word];
    // CLAMPED: masked to `bits` <= 8 low bits before the cast.
    ((w >> ((index % per_word) * bits)) & ((1 << bits) - 1)) as u8
}

/// Vectorized unpack→dequant of one group span: 8 codes per round are
/// sheared out of a broadcast word with a per-lane variable shift
/// (`vpsrlvd`), masked, converted, and evaluated as `scale * (code - zero)`
/// — the exact f32 expression of both scalar paths (the LUT entry for code
/// `q` *is* `scale * (q - zero)`), so this is bit-identical to scalar by
/// construction.  Groups need not align to word boundaries (bits=3 packs
/// 10 codes/word): the span runs scalar to the first boundary, vectorizes
/// whole words (codes never straddle words — `pack_values` flushes early),
/// and finishes any ragged word/group tail scalar.  Callable only when
/// `per_word >= 8`, i.e. bits ≤ 4 — the serving bit widths.
///
/// # Safety
/// Caller must ensure the CPU supports AVX2 (dispatch goes through
/// `simd::level()`), that `per_word >= 8`, and that
/// `row_words[(start + out.len() - 1) / per_word]` is in bounds; the
/// vector stores cover `out[..]` exactly, 8 lanes at a time.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequant_span_avx2(
    row_words: &[u32],
    bits: usize,
    scale: f32,
    zero: f32,
    start: usize,
    out: &mut [f32],
) {
    use std::arch::x86_64::*;
    let per_word = 32 / bits;
    debug_assert!(per_word >= 8);
    let mask = (1u32 << bits) - 1;
    let end = start + out.len();
    let maskv = _mm256_set1_epi32(mask as i32);
    let scalev = _mm256_set1_ps(scale);
    let zerov = _mm256_set1_ps(zero);
    let rounds = per_word / 8;
    let mut c = start;
    while c < end && c % per_word != 0 {
        let code = (row_words[c / per_word] >> ((c % per_word) * bits)) & mask;
        out[c - start] = scale * (code as f32 - zero);
        c += 1;
    }
    while c + per_word <= end {
        let wv = _mm256_set1_epi32(row_words[c / per_word] as i32);
        for r in 0..rounds {
            // lane l of round r extracts code r*8 + l of the word
            let base = (r * 8 * bits) as i32;
            let b = bits as i32;
            let shifts = _mm256_setr_epi32(
                base,
                base + b,
                base + 2 * b,
                base + 3 * b,
                base + 4 * b,
                base + 5 * b,
                base + 6 * b,
                base + 7 * b,
            );
            let codes = _mm256_and_si256(_mm256_srlv_epi32(wv, shifts), maskv);
            let vals = _mm256_mul_ps(scalev, _mm256_sub_ps(_mm256_cvtepi32_ps(codes), zerov));
            _mm256_storeu_ps(out.as_mut_ptr().add(c - start + r * 8), vals);
        }
        // per_word % 8 codes (bits=3: codes 8..10) finish scalar
        for t in rounds * 8..per_word {
            let code = (row_words[c / per_word] >> (t * bits)) & mask;
            out[c - start + t] = scale * (code as f32 - zero);
        }
        c += per_word;
    }
    while c < end {
        let code = (row_words[c / per_word] >> ((c % per_word) * bits)) & mask;
        out[c - start] = scale * (code as f32 - zero);
        c += 1;
    }
}

/// One activation row against a transposed weight tile:
/// `out[j] = Σ_kk ar[kk] · tile_t[kk·nb + j]` with the kk loop outermost.
/// Per output element this is the exact kk-sequential one-mul-one-add
/// accumulation of [`ops::matmul_nt`]'s 4-wide blocked kernel, so every
/// dispatch tier below is bit-identical to the dense reference; the
/// vector tiers just compute 4 (SSE2) or 8 (AVX2) independent output
/// columns per instruction.  `nb` must be a multiple of 4 — the caller
/// splits off `matmul_nt`'s per-column `dot`-scheme tail separately.
fn gemm_row(ar: &[f32], tile_t: &[f32], nb: usize, out: &mut [f32]) {
    debug_assert_eq!(nb % 4, 0);
    debug_assert_eq!(out.len(), nb);
    debug_assert_eq!(tile_t.len(), ar.len() * nb);
    if nb == 0 {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    match simd::level() {
        // SAFETY: the dispatch level only reports Avx2 when the CPU has it.
        simd::SimdLevel::Avx2 => return unsafe { gemm_row_avx2(ar, tile_t, nb, out) },
        simd::SimdLevel::Sse2 => return gemm_row_sse2(ar, tile_t, nb, out),
        simd::SimdLevel::Scalar => {}
    }
    gemm_row_scalar(ar, tile_t, nb, out);
}

fn gemm_row_scalar(ar: &[f32], tile_t: &[f32], nb: usize, out: &mut [f32]) {
    out.fill(0.0);
    for (kk, &av) in ar.iter().enumerate() {
        let trow = &tile_t[kk * nb..(kk + 1) * nb];
        for (o, &w) in out.iter_mut().zip(trow) {
            *o += av * w;
        }
    }
}

// SSE2 is the x86-64 architecture baseline, so no runtime probe or
// `target_feature` gate is needed; explicit `_mm_mul_ps` + `_mm_add_ps`
// (never FMA) keeps every lane IEEE-identical to the scalar loop.
#[cfg(target_arch = "x86_64")]
fn gemm_row_sse2(ar: &[f32], tile_t: &[f32], nb: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let k = ar.len();
    let mut j = 0;
    while j + 16 <= nb {
        // SAFETY: j + 16 <= nb so the four unaligned 4-lane load/store
        // blocks at j..j+16 stay inside `out` (len nb) and each
        // `tile_t[kk*nb + j ..]` access inside `tile_t` (len k*nb);
        // `kk < k` bounds get_unchecked on `ar`. SSE2 is the x86-64
        // baseline, so the intrinsics are always available.
        unsafe {
            let mut a0 = _mm_setzero_ps();
            let mut a1 = _mm_setzero_ps();
            let mut a2 = _mm_setzero_ps();
            let mut a3 = _mm_setzero_ps();
            for kk in 0..k {
                let av = _mm_set1_ps(*ar.get_unchecked(kk));
                let t = tile_t.as_ptr().add(kk * nb + j);
                a0 = _mm_add_ps(a0, _mm_mul_ps(av, _mm_loadu_ps(t)));
                a1 = _mm_add_ps(a1, _mm_mul_ps(av, _mm_loadu_ps(t.add(4))));
                a2 = _mm_add_ps(a2, _mm_mul_ps(av, _mm_loadu_ps(t.add(8))));
                a3 = _mm_add_ps(a3, _mm_mul_ps(av, _mm_loadu_ps(t.add(12))));
            }
            let o = out.as_mut_ptr().add(j);
            _mm_storeu_ps(o, a0);
            _mm_storeu_ps(o.add(4), a1);
            _mm_storeu_ps(o.add(8), a2);
            _mm_storeu_ps(o.add(12), a3);
        }
        j += 16;
    }
    while j < nb {
        // SAFETY: nb % 4 == 0 (debug-asserted by gemm_row) and j < nb, so
        // the 4-lane load/store at j..j+4 stays inside `out` and
        // `tile_t[kk*nb + j ..]`; SSE2 is the x86-64 baseline.
        unsafe {
            let mut acc = _mm_setzero_ps();
            for kk in 0..k {
                let av = _mm_set1_ps(*ar.get_unchecked(kk));
                let t = tile_t.as_ptr().add(kk * nb + j);
                acc = _mm_add_ps(acc, _mm_mul_ps(av, _mm_loadu_ps(t)));
            }
            _mm_storeu_ps(out.as_mut_ptr().add(j), acc);
        }
        j += 4;
    }
}

/// # Safety
/// Caller must ensure the CPU supports AVX2 (dispatch goes through
/// `simd::level()`) and `nb % 4 == 0` with `out.len() == nb`,
/// `tile_t.len() == ar.len() * nb`: every 64/8/4-lane block below stays
/// inside those bounds.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_row_avx2(ar: &[f32], tile_t: &[f32], nb: usize, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let k = ar.len();
    let mut j = 0;
    // a full ROW_TILE fits in 8 live ymm accumulators: one pass over the
    // activation row and the transposed tile computes all 64 columns
    while j + 64 <= nb {
        let mut acc = [_mm256_setzero_ps(); 8];
        for kk in 0..k {
            let av = _mm256_set1_ps(*ar.get_unchecked(kk));
            let t = tile_t.as_ptr().add(kk * nb + j);
            for (l, a) in acc.iter_mut().enumerate() {
                *a = _mm256_add_ps(*a, _mm256_mul_ps(av, _mm256_loadu_ps(t.add(8 * l))));
            }
        }
        for (l, a) in acc.iter().enumerate() {
            _mm256_storeu_ps(out.as_mut_ptr().add(j + 8 * l), *a);
        }
        j += 64;
    }
    while j + 8 <= nb {
        let mut acc = _mm256_setzero_ps();
        for kk in 0..k {
            let av = _mm256_set1_ps(*ar.get_unchecked(kk));
            let t = tile_t.as_ptr().add(kk * nb + j);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, _mm256_loadu_ps(t)));
        }
        _mm256_storeu_ps(out.as_mut_ptr().add(j), acc);
        j += 8;
    }
    if j < nb {
        // nb % 8 == 4: one xmm block
        let mut acc = _mm_setzero_ps();
        for kk in 0..k {
            let av = _mm_set1_ps(*ar.get_unchecked(kk));
            acc = _mm_add_ps(acc, _mm_mul_ps(av, _mm_loadu_ps(tile_t.as_ptr().add(kk * nb + j))));
        }
        _mm_storeu_ps(out.as_mut_ptr().add(j), acc);
    }
}

impl PackedTensor {
    /// Pack a [`GroupQuant`].
    pub fn pack(q: &GroupQuant) -> PackedTensor {
        let bits = q.scheme.bits;
        let per_word = 32 / bits;
        let words_per_row = q.cols.div_ceil(per_word);
        let mut words = Vec::with_capacity(q.rows * words_per_row);
        for r in 0..q.rows {
            let row_words = pack_values(
                q.codes[r * q.cols..(r + 1) * q.cols].iter().copied(),
                bits,
            );
            debug_assert!(row_words.len() <= words_per_row);
            words.extend(&row_words);
            words.extend(std::iter::repeat(0).take(words_per_row - row_words.len()));
        }
        let scales_f16 = q.scales.iter().map(|&s| f32_to_f16_bits(s)).collect();
        // CLAMPED: GroupQuant zero-points are clamped to [0, qmax] by the
        // codec (the PR-2 single-sign-group fix), so z fits `bits` bits.
        let zero_words = pack_values(q.zeros.iter().map(|&z| z as u8), bits.max(1));
        PackedTensor {
            scheme: q.scheme,
            rows: q.rows,
            cols: q.cols,
            words,
            words_per_row,
            scales_f16,
            zero_words,
        }
    }

    /// Unpack back to dense dequantized weights (f16 scale precision —
    /// this is the deployment-faithful dequant).  Built on the same fused
    /// row decoder the serving kernels use, so packed-direct vs
    /// unpack-to-dense parity holds by construction.
    pub fn unpack(&self) -> Tensor {
        let mut out = Tensor::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            self.dequant_row_into(r, out.row_mut(r));
        }
        out
    }

    /// Scale (f16-rounded, deployment precision) and zero-point of group
    /// `g` of row `r`.
    pub fn group_params(&self, r: usize, g: usize) -> (f32, f32) {
        let n_groups = self.cols / self.scheme.group;
        debug_assert!(r < self.rows && g < n_groups);
        let idx = r * n_groups + g;
        (
            f16_bits_to_f32(self.scales_f16[idx]),
            unpack_value(&self.zero_words, self.scheme.bits, idx) as f32,
        )
    }

    /// Integer code at `(r, c)`.
    pub fn code(&self, r: usize, c: usize) -> u8 {
        let row_words = &self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
        unpack_value(row_words, self.scheme.bits, c)
    }

    /// Iterate one row's groups as `(group index, scale, zero)` — the
    /// walk order of the fused kernels, exposed for tests and tooling.
    pub fn row_groups(&self, r: usize) -> impl Iterator<Item = (usize, f32, f32)> + '_ {
        (0..self.cols / self.scheme.group).map(move |g| {
            let (s, z) = self.group_params(r, g);
            (g, s, z)
        })
    }

    /// Fused unpack→dequant of one row into `out` (len `cols`), group by
    /// group, without touching any other row.
    ///
    /// Hot-loop shape: when a group holds more elements than the code space
    /// (`2^bits <= 256` entries), the per-group dequant values
    /// `scale * (q - zero)` are precomputed once into a lookup table and
    /// each element becomes an unpack + table load, instead of re-running
    /// the float multiply/subtract per element.  The LUT entry for code `q`
    /// is the exact same f32 expression the direct path evaluates, so both
    /// paths are bit-identical (the direct path is kept for sparse groups
    /// where filling `2^bits` entries would outweigh the group itself, and
    /// doubles as the reference in `dequant_lut_bit_identical_to_direct`).
    ///
    /// At [`simd::SimdLevel::Avx2`] and bits ≤ 4, the unpack+dequant runs
    /// 8 codes per instruction through [`dequant_span_avx2`] — bit-identical
    /// to both scalar paths (same f32 expression per element), pinned by
    /// `simd_dequant_bit_identical_to_scalar`.  Bits ≥ 5 pack fewer than 8
    /// codes per word and stay scalar at every tier (not serving widths).
    pub fn dequant_row_into(&self, r: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.cols, "dequant_row_into: bad buffer");
        // bytes of f32 produced by unpack+dequant, wherever it runs
        // (standalone or inside the fused GEMM tiles); gated to one relaxed
        // load when tracing is off
        crate::obs::kernel::add_dequant_bytes(out.len() * 4);
        let bits = self.scheme.bits;
        let per_word = 32 / bits;
        let mask = (1u32 << bits) - 1;
        let group = self.scheme.group;
        let n_levels = 1usize << bits;
        let row_words = &self.words[r * self.words_per_row..(r + 1) * self.words_per_row];
        #[cfg(target_arch = "x86_64")]
        if per_word >= 8 && simd::level() == simd::SimdLevel::Avx2 {
            for (g, scale, zero) in self.row_groups(r) {
                let a = g * group;
                // SAFETY: dispatch level established AVX2 support.
                unsafe {
                    dequant_span_avx2(row_words, bits, scale, zero, a, &mut out[a..a + group])
                };
            }
            return;
        }
        let mut lut = [0.0f32; 256];
        for (g, scale, zero) in self.row_groups(r) {
            let a = g * group;
            if n_levels <= group {
                for (q, slot) in lut[..n_levels].iter_mut().enumerate() {
                    *slot = scale * (q as f32 - zero);
                }
                for (i, o) in out[a..a + group].iter_mut().enumerate() {
                    let c = a + i;
                    let code = (row_words[c / per_word] >> ((c % per_word) * bits)) & mask;
                    *o = lut[code as usize];
                }
            } else {
                for (i, o) in out[a..a + group].iter_mut().enumerate() {
                    let c = a + i;
                    let code =
                        ((row_words[c / per_word] >> ((c % per_word) * bits)) & mask) as f32;
                    *o = scale * (code - zero);
                }
            }
        }
    }

    /// Fused dequant of rows `[r0, r0 + n)` into a `[n, cols]` scratch tile.
    pub fn dequant_rows_into(&self, r0: usize, n: usize, out: &mut [f32]) {
        assert_eq!(out.len(), n * self.cols, "dequant_rows_into: bad buffer");
        for (i, chunk) in out.chunks_mut(self.cols).enumerate() {
            self.dequant_row_into(r0 + i, chunk);
        }
    }

    /// Fused unpack→dequant→GEMM/GEMV serving kernel:
    /// `x [m, cols] @ deq(W) [rows, cols]^T + bias`, computed directly from
    /// the packed codes.  Work is tiled over [`ROW_TILE`] output rows (the
    /// tiles decode + multiply in parallel on the thread pool), so at most
    /// one small dense tile per worker is ever live — the full quantized
    /// matrix is never densified.  Bit-identical to
    /// `ops::linear(x, &self.unpack(), bias)`.
    pub fn linear(&self, x: &Tensor, bias: &[f32]) -> Tensor {
        let mut out = Tensor::zeros(x.rows, self.rows);
        self.linear_into(x, bias, &mut out);
        out
    }

    /// [`PackedTensor::linear`] into a preallocated output.
    pub fn linear_into(&self, x: &Tensor, bias: &[f32], out: &mut Tensor) {
        // one relaxed atomic load when tracing is off (`obs::kernel`);
        // per-tier time/bytes/rows when on — the GB/s counters in bench
        // JSON and the Prometheus page come from exactly this accounting
        let t = crate::obs::kernel::gemm_timer();
        self.linear_into_raw(x, bias, out);
        t.finish(x.rows, self.nbytes());
    }

    /// The uninstrumented kernel body of [`PackedTensor::linear_into`].
    /// Exposed (hidden) so `kernel_microbench --smoke` can measure the
    /// fused GEMV path with the counter gate compiled out of the loop and
    /// assert the tracing-disabled overhead stays under 1%.
    #[doc(hidden)]
    pub fn linear_into_raw(&self, x: &Tensor, bias: &[f32], out: &mut Tensor) {
        assert_eq!(x.cols, self.cols, "packed linear: in-dim mismatch");
        assert_eq!(bias.len(), self.rows, "packed linear: bias mismatch");
        assert_eq!(out.shape(), (x.rows, self.rows), "packed linear: bad out");
        let (m, k, n) = (x.rows, self.cols, self.rows);
        if m == 0 {
            return;
        }
        let n_tiles = n.div_ceil(ROW_TILE);
        // Small calls — notably the per-token decode GEMVs, which already
        // run under the server's per-sequence parallelism — stay serial:
        // spawning scoped threads per tile would cost more than the tiles'
        // work.  Shared size threshold with matmul_nt_par; the result is
        // identical either way (tiles are independent and order-preserved).
        let threads =
            if m * k * n < ops::par_threshold() { 1 } else { pool::num_threads().min(n_tiles) };
        let tiles: Vec<Vec<f32>> = pool::parallel_map(n_tiles, threads, |ti| {
            let j0 = ti * ROW_TILE;
            let nb = ROW_TILE.min(n - j0);
            let mut block = vec![0.0f32; m * nb];
            self.gemm_tile(x, j0, nb, &mut block);
            block
        });
        for (ti, block) in tiles.iter().enumerate() {
            let j0 = ti * ROW_TILE;
            let nb = block.len() / m;
            for i in 0..m {
                out.data[i * n + j0..i * n + j0 + nb]
                    .copy_from_slice(&block[i * nb..(i + 1) * nb]);
            }
        }
        ops::add_bias(out, bias);
    }

    /// Multi-row serving entry point: identical math to
    /// [`PackedTensor::linear`], named for call sites that batch `k`
    /// activation rows (chunked verify, batched prefill, multi-row
    /// `forward_chunk`) so the weight-traffic amortization is explicit —
    /// every ROW_TILE of packed rows is decoded ONCE and multiplied against
    /// all `k` rows, instead of re-streamed/re-dequantized per row as `k`
    /// independent GEMVs would.  Bit-identical to `k` single-row
    /// [`PackedTensor::linear`] calls (each output element's accumulation
    /// never depends on `x.rows`; pinned by
    /// `linear_batch_bit_identical_to_row_calls`).
    pub fn linear_batch(&self, x: &Tensor, bias: &[f32]) -> Tensor {
        self.linear(x, bias)
    }

    /// Decode one ROW_TILE of weight rows once and multiply all `m`
    /// activation rows against it — the cache-blocked core of
    /// [`PackedTensor::linear_into`].  The columns `ops::matmul_nt` would
    /// cover with its 4-wide blocked kernel are dequantized *transposed*
    /// into a `[k, nb4]` tile so [`gemm_row`] reads contiguous SIMD lanes;
    /// the ≤3 `dot`-tail columns (final tile only — ROW_TILE is a multiple
    /// of 4, so the tile-local split equals the whole-matrix split) stay
    /// row-major and reproduce `dot`'s 8-accumulator scheme exactly.
    fn gemm_tile(&self, x: &Tensor, j0: usize, nb: usize, block: &mut [f32]) {
        let (m, k) = (x.rows, self.cols);
        let nb4 = nb & !3;
        let mut tile_t = vec![0.0f32; k * nb4];
        let mut rowbuf = vec![0.0f32; k];
        for j in 0..nb4 {
            self.dequant_row_into(j0 + j, &mut rowbuf);
            for (kk, &v) in rowbuf.iter().enumerate() {
                tile_t[kk * nb4 + j] = v;
            }
        }
        let tail = nb - nb4;
        let mut tail_rows = vec![0.0f32; tail * k];
        self.dequant_rows_into(j0 + nb4, tail, &mut tail_rows);
        for i in 0..m {
            let ar = &x.data[i * k..(i + 1) * k];
            let orow = &mut block[i * nb..(i + 1) * nb];
            gemm_row(ar, &tile_t, nb4, &mut orow[..nb4]);
            for t in 0..tail {
                orow[nb4 + t] = ops::dot(ar, &tail_rows[t * k..(t + 1) * k]);
            }
        }
    }

    /// Row-range view `[r0, r0 + n)` as a standalone [`PackedTensor`] —
    /// the tensor-parallel building block of [`crate::serve::shard`]: each
    /// shard owns the packed slice of the output rows it computes, so a
    /// sharded linear is N disjoint column ranges of the whole output.
    ///
    /// Codes and scales are row-addressable and slice directly; zero-points
    /// are packed contiguously across all `(row, group)` indices, so the
    /// slice's zeros are re-packed from scratch (values preserved exactly —
    /// packing is lossless).  When the slice covers whole `ROW_TILE`
    /// blocks (`r0 % 64 == 0`, and `n % 64 == 0` unless the slice runs to
    /// the last row) the sliced [`PackedTensor::linear`] is
    /// **bit-identical** to the matching column range of the whole tensor's
    /// `linear`: tile boundaries and the 4-wide/`dot`-tail column split
    /// land on the same rows either way (pinned by
    /// `slice_rows_linear_matches_whole`).
    pub fn slice_rows(&self, r0: usize, n: usize) -> PackedTensor {
        assert!(r0 + n <= self.rows, "slice_rows: {r0}+{n} exceeds {} rows", self.rows);
        let n_groups = self.cols / self.scheme.group;
        let bits = self.scheme.bits;
        let zeros = (r0 * n_groups..(r0 + n) * n_groups)
            .map(|i| unpack_value(&self.zero_words, bits, i));
        PackedTensor {
            scheme: self.scheme,
            rows: n,
            cols: self.cols,
            words: self.words[r0 * self.words_per_row..(r0 + n) * self.words_per_row].to_vec(),
            words_per_row: self.words_per_row,
            scales_f16: self.scales_f16[r0 * n_groups..(r0 + n) * n_groups].to_vec(),
            zero_words: pack_values(zeros, bits.max(1)),
        }
    }

    /// Total storage in bytes (codes + scales + zeros).
    pub fn nbytes(&self) -> usize {
        self.words.len() * 4 + self.scales_f16.len() * 2 + self.zero_words.len() * 4
    }

    /// Measured bits per parameter — the honest Table-3 column.
    pub fn bits_per_param(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::{dequantize, quantize};
    use crate::util::{propcheck, rng::Pcg64};

    #[test]
    fn f16_roundtrip_exactish() {
        for &x in &[0.0f32, 1.0, -2.5, 0.333, 1e-3, 65504.0, -1e-6] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let tol = (x.abs() * 1e-3).max(1e-7);
            assert!((x - y).abs() <= tol, "{x} -> {y}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e30)).is_infinite());
    }

    #[test]
    fn miri_small_pack_roundtrip() {
        // Miri-sized: one row, three 4-bit groups, fixed inputs. The
        // exhaustive and property tests in this module are too slow under
        // the interpreter; the nightly verify workflow (verify.yml) runs
        // `cargo miri test -- miri_` with INVAREXPLORE_SIMD=scalar so the
        // unsafe packed kernels get checked on their scalar path.
        let scheme = QuantScheme::new(4, 32);
        let w = Tensor::from_vec(1, 96, (0..96).map(|i| i as f32 * 0.25 - 12.0).collect());
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        let dense = packed.unpack();
        let mut row = vec![0.0f32; 96];
        packed.dequant_row_into(0, &mut row);
        assert_eq!(row.as_slice(), dense.row(0));
    }

    #[test]
    fn f16_u16_exhaustive_roundtrip() {
        // every finite f16 bit pattern must survive f16 -> f32 -> f16
        // bit-exactly; infinities map to themselves and NaNs stay NaN.
        for h in 0..=u16::MAX {
            let f = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(f);
            if f.is_nan() {
                assert_eq!(back & 0x7c00, 0x7c00, "{h:#06x}: NaN lost exponent");
                assert_ne!(back & 0x03ff, 0, "{h:#06x}: NaN became infinity");
            } else {
                assert_eq!(back, h, "{h:#06x} -> {f} -> {back:#06x}");
            }
        }
    }

    #[test]
    fn pack_unpack_preserves_codes() {
        propcheck::check("pack/unpack code fidelity", 48, |rng| {
            // bits 1..=8 including the non-divisors of 32 (5, 6, 7), over
            // centered, shifted, and (at |shift| = 3) mostly single-sign
            // weight distributions — the zero-point clamp regression surface
            let bits = rng.below(8) + 1;
            let scheme = QuantScheme::new(bits, 32);
            let rows = rng.below(5) + 1;
            let cols = 32 * (rng.below(3) + 1);
            let shift = *rng.choice(&[-3.0f32, -0.75, 0.0, 0.75, 3.0]);
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32 + shift).collect(),
            );
            let q = quantize(&w, scheme);
            let packed = PackedTensor::pack(&q);
            let n_groups = cols / scheme.group;
            // codes and zero-points survive packing exactly
            for r in 0..rows {
                for c in 0..cols {
                    if packed.code(r, c) != q.codes[r * cols + c] {
                        return Err(format!("code mismatch at ({r},{c})"));
                    }
                }
                for (g, _scale, zero) in packed.row_groups(r) {
                    let zq = q.zeros[r * n_groups + g];
                    if zero != zq {
                        return Err(format!("zero mismatch row {r} group {g}: {zero} vs {zq}"));
                    }
                }
            }
            // unpack differs from exact dequant only by f16 scale rounding
            let exact = dequantize(&q);
            let unpacked = packed.unpack();
            for (a, b) in exact.data.iter().zip(&unpacked.data) {
                let tol = (a.abs() * 2e-3).max(1e-4);
                if (a - b).abs() > tol {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_sign_groups_pack_faithfully() {
        // REGRESSION (PR 2): all-positive groups used to produce a negative
        // zero-point that saturated to 0 in pack's `z as u8` cast, and
        // all-negative groups a zero > qmax that was truncated under the
        // pack mask — so unpack() silently disagreed with dequantize().
        // The codec now clamps zero into [0, qmax]; packed↔dense dequant
        // parity must hold for single-sign groups too.
        let mut rng = Pcg64::new(11);
        for &(lo, hi) in &[(0.5f32, 2.5f32), (-2.5, -0.5)] {
            for bits in [1usize, 2, 3, 4, 8] {
                let scheme = QuantScheme::new(bits, 32);
                let w = Tensor::from_vec(
                    2,
                    64,
                    (0..128).map(|_| lo + (hi - lo) * rng.uniform() as f32).collect(),
                );
                let q = quantize(&w, scheme);
                let qmax = scheme.qmax();
                assert!(
                    q.zeros.iter().all(|&z| (0.0..=qmax).contains(&z)),
                    "codec zero escaped [0, qmax] (bits {bits}, range {lo}..{hi})"
                );
                let exact = dequantize(&q);
                let unpacked = PackedTensor::pack(&q).unpack();
                for (a, b) in exact.data.iter().zip(&unpacked.data) {
                    let tol = (a.abs() * 2e-3).max(1e-4);
                    assert!(
                        (a - b).abs() <= tol,
                        "packed dequant diverged: {a} vs {b} (bits {bits}, range {lo}..{hi})"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_linear_bit_identical_to_unpack() {
        // the serving-path acceptance pin: the fused packed GEMM must equal
        // a dense ops::linear over unpack() BIT-FOR-BIT, across row counts
        // that exercise full tiles, partial tiles, and non-multiple-of-4
        // matmul tails.
        propcheck::check("packed linear == dense linear over unpack()", 16, |rng| {
            let bits = rng.below(4) + 1;
            let scheme = QuantScheme::new(bits, 32);
            let rows = rng.below(150) + 1;
            let cols = 32 * (rng.below(3) + 1);
            let m = rng.below(3) + 1;
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32).collect(),
            );
            let packed = PackedTensor::pack(&quantize(&w, scheme));
            let x = Tensor::from_vec(
                m,
                cols,
                (0..m * cols).map(|_| rng.normal() as f32).collect(),
            );
            let bias: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
            let fused = packed.linear(&x, &bias);
            let dense = crate::tensor::ops::linear(&x, &packed.unpack(), &bias);
            propcheck::ensure(
                fused.data == dense.data,
                format!("bitwise mismatch at rows={rows} cols={cols} m={m} bits={bits}"),
            )
        });
    }

    #[test]
    fn simd_dequant_bit_identical_to_scalar() {
        // tentpole pin: for every serving bit width × group size × ragged
        // word tail, the AVX2 unpack+dequant must reproduce the scalar path
        // bit-for-bit.  The sweep covers word-unaligned group starts
        // (bits=3 packs 10 codes/word, so groups start mid-word from the
        // second group on) and partial trailing words; on hardware without
        // AVX2 both legs run scalar and the test degenerates to reflexivity.
        let _g = simd::test_guard();
        let prev = simd::level();
        let mut rng = Pcg64::new(7);
        for bits in 1..=4usize {
            for group in [16usize, 32, 64, 128] {
                for mult in 1..=3usize {
                    let cols = group * mult;
                    let shift = *rng.choice(&[-2.0f32, 0.0, 2.0]);
                    let w = Tensor::from_vec(
                        3,
                        cols,
                        (0..3 * cols).map(|_| rng.normal() as f32 + shift).collect(),
                    );
                    let packed = PackedTensor::pack(&quantize(&w, QuantScheme::new(bits, group)));
                    let mut scalar = vec![0.0f32; cols];
                    let mut vector = vec![0.0f32; cols];
                    for r in 0..3 {
                        simd::set_simd_level(simd::SimdLevel::Scalar);
                        packed.dequant_row_into(r, &mut scalar);
                        simd::set_simd_level(simd::detect());
                        packed.dequant_row_into(r, &mut vector);
                        for c in 0..cols {
                            assert_eq!(
                                scalar[c].to_bits(),
                                vector[c].to_bits(),
                                "bits={bits} group={group} cols={cols} ({r},{c}): {} vs {}",
                                scalar[c],
                                vector[c]
                            );
                        }
                    }
                }
            }
        }
        simd::set_simd_level(prev);
    }

    #[test]
    fn simd_gemm_bit_identical_across_levels() {
        // the fused GEMM tile kernel must produce the same bits at every
        // dispatch tier (Scalar / SSE2 / AVX2, clamped to hardware), over
        // full 64-row tiles, partial tiles, every lane-remainder shape
        // (8-wide main, 4-wide xmm block), and non-multiple-of-4 dot tails.
        let _g = simd::test_guard();
        let prev = simd::level();
        let mut rng = Pcg64::new(9);
        for &(rows, m) in &[(64usize, 1usize), (70, 3), (129, 4), (30, 2)] {
            let cols = 96;
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32).collect(),
            );
            let packed = PackedTensor::pack(&quantize(&w, QuantScheme::new(2, 32)));
            let x =
                Tensor::from_vec(m, cols, (0..m * cols).map(|_| rng.normal() as f32).collect());
            let bias: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
            simd::set_simd_level(simd::SimdLevel::Scalar);
            let want = packed.linear(&x, &bias);
            for lvl in [simd::SimdLevel::Sse2, simd::SimdLevel::Avx2] {
                simd::set_simd_level(lvl);
                let got = packed.linear(&x, &bias);
                for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{lvl:?} rows={rows} m={m} idx={i}: {a} vs {b}"
                    );
                }
            }
        }
        simd::set_simd_level(prev);
    }

    #[test]
    fn linear_batch_bit_identical_to_row_calls() {
        // the multi-row entry point must equal k independent single-row
        // GEMVs bit-for-bit.  Geometry crosses ops::par_threshold() for the
        // batched call (parallel tiles) while each row call stays serial —
        // so this also pins serial == parallel for the packed GEMM (the
        // hoisted-threshold satellite).
        let mut rng = Pcg64::new(3);
        let (rows, cols, m) = (256usize, 128usize, 8usize);
        assert!(m * cols * rows >= ops::par_threshold());
        assert!(cols * rows < ops::par_threshold());
        let w = Tensor::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        );
        let packed = PackedTensor::pack(&quantize(&w, QuantScheme::new(3, 32)));
        let x = Tensor::from_vec(m, cols, (0..m * cols).map(|_| rng.normal() as f32).collect());
        let bias: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
        let batched = packed.linear_batch(&x, &bias);
        for i in 0..m {
            let xi = Tensor::from_vec(1, cols, x.row(i).to_vec());
            let row = packed.linear(&xi, &bias);
            for (c, (a, b)) in batched.row(i).iter().zip(&row.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i} col {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dequant_lut_bit_identical_to_direct() {
        // the LUT fast path (2^bits <= group) must reproduce the direct
        // per-element `scale * (q - zero)` bit-for-bit, for every bit width
        // on both sides of the gate (bits 8 over group 32 takes the direct
        // path; everything else below takes the LUT).
        propcheck::check("dequant LUT == direct dequant", 24, |rng| {
            let bits = rng.below(8) + 1;
            let group = *rng.choice(&[16usize, 32, 64]);
            let scheme = QuantScheme::new(bits, group);
            let rows = rng.below(4) + 1;
            let cols = group * (rng.below(3) + 1);
            let shift = *rng.choice(&[-2.0f32, 0.0, 2.0]);
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32 + shift).collect(),
            );
            let packed = PackedTensor::pack(&quantize(&w, scheme));
            let mut row = vec![0.0f32; cols];
            for r in 0..rows {
                packed.dequant_row_into(r, &mut row);
                // reference: the direct formula over code/group accessors
                for (g, scale, zero) in packed.row_groups(r) {
                    for c in g * group..(g + 1) * group {
                        let want = scale * (packed.code(r, c) as f32 - zero);
                        if row[c].to_bits() != want.to_bits() {
                            return Err(format!(
                                "bits={bits} group={group} ({r},{c}): {} vs {want}",
                                row[c]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dequant_row_matches_unpack() {
        let mut rng = Pcg64::new(4);
        let scheme = QuantScheme::new(3, 32);
        let w = Tensor::from_vec(5, 96, (0..5 * 96).map(|_| rng.normal() as f32).collect());
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        let dense = packed.unpack();
        let mut row = vec![0.0f32; 96];
        for r in 0..5 {
            packed.dequant_row_into(r, &mut row);
            assert_eq!(row.as_slice(), dense.row(r), "row {r}");
        }
    }

    #[test]
    fn bits_per_param_close_to_nominal() {
        let mut rng = Pcg64::new(1);
        let scheme = QuantScheme::new(2, 64);
        let w = Tensor::from_vec(
            64,
            1024,
            (0..64 * 1024).map(|_| rng.normal() as f32).collect(),
        );
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        let bpp = packed.bits_per_param();
        // 2 bits + 16/64 scale + 2/64 zero ≈ 2.28, plus padding slack
        assert!(bpp > 2.0 && bpp < 2.6, "bpp {bpp}");
        // memory saving vs f32 ≥ 85% (paper's claim vs FP16 is 85% at 2.125)
        let savings = 1.0 - packed.nbytes() as f64 / (64.0 * 1024.0 * 2.0); // vs f16
        assert!(savings > 0.8, "savings {savings}");
    }

    #[test]
    fn slice_rows_preserves_codes_scales_and_zeros() {
        // the slice must reproduce codes, scales, and zero-points of its
        // row range exactly — including the zero-point re-pack across the
        // non-word-aligned widths (bits 3 packs 10 zeros/word)
        propcheck::check("slice_rows fidelity", 32, |rng| {
            let bits = rng.below(4) + 1;
            let scheme = QuantScheme::new(bits, 32);
            let rows = rng.below(120) + 2;
            let cols = 32 * (rng.below(3) + 1);
            let shift = *rng.choice(&[-2.0f32, 0.0, 2.0]);
            let w = Tensor::from_vec(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal() as f32 + shift).collect(),
            );
            let packed = PackedTensor::pack(&quantize(&w, scheme));
            let r0 = rng.below(rows - 1);
            let n = rng.below(rows - r0) + 1;
            let sliced = packed.slice_rows(r0, n);
            for r in 0..n {
                for c in 0..cols {
                    if sliced.code(r, c) != packed.code(r0 + r, c) {
                        return Err(format!("code mismatch at ({r},{c}), r0={r0}"));
                    }
                }
                for (g, s, z) in sliced.row_groups(r) {
                    let (sw, zw) = packed.group_params(r0 + r, g);
                    if s.to_bits() != sw.to_bits() || z != zw {
                        return Err(format!("group params mismatch row {r} group {g}"));
                    }
                }
            }
            propcheck::ensure(
                sliced.unpack().data
                    == packed.unpack().data[r0 * cols..(r0 + n) * cols].to_vec(),
                format!("unpack mismatch r0={r0} n={n}"),
            )
        });
    }

    #[test]
    fn slice_rows_linear_matches_whole() {
        // the tensor-parallel pin: tile-aligned row slices computed
        // independently and concatenated must equal the whole-tensor fused
        // linear BIT-FOR-BIT (this is what makes sharded serving exact).
        // 150 rows = two full 64-row tiles + a 22-row tail, split 64/86.
        let mut rng = Pcg64::new(21);
        let (rows, cols, m) = (150usize, 96usize, 3usize);
        let w = Tensor::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        );
        let packed = PackedTensor::pack(&quantize(&w, QuantScheme::new(2, 32)));
        let x = Tensor::from_vec(m, cols, (0..m * cols).map(|_| rng.normal() as f32).collect());
        let bias: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
        let whole = packed.linear(&x, &bias);
        for &(r0, n) in &[(0usize, 64usize), (64, 86), (0, 128), (128, 22), (0, 150)] {
            let part = packed.slice_rows(r0, n).linear(&x, &bias[r0..r0 + n]);
            for i in 0..m {
                for j in 0..n {
                    let a = part.data[i * n + j];
                    let b = whole.data[i * rows + r0 + j];
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "slice ({r0},{n}) row {i} col {j}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn words_per_row_padding() {
        // cols=96, bits=3 -> per_word=10 -> 10 words/row (96/10 = 9.6)
        let scheme = QuantScheme::new(3, 32);
        let w = Tensor::zeros(2, 96);
        let packed = PackedTensor::pack(&quantize(&w, scheme));
        assert_eq!(packed.words_per_row, 10);
        assert_eq!(packed.words.len(), 20);
    }
}

//! Weight clipping for groupwise quantization.
//!
//! AWQ and OmniQuant both shrink each group's quantization range to trade
//! clipping error for resolution ("weight clipping to alleviate outlier
//! weights", paper §4.2).  The range `[min, max]` is shrunk symmetrically
//! around its midpoint by a ratio `r ≤ 1`, chosen per group from a
//! candidate grid by minimizing reconstruction MSE.

use super::scheme::QuantScheme;
use crate::tensor::Tensor;

/// AWQ-style candidate grid (coarse).
pub const AWQ_CLIP_GRID: [f32; 5] = [1.0, 0.95, 0.9, 0.85, 0.8];

/// OmniQuant-style candidate grid (finer — its clip is "learned"; grid
/// search is the documented SGD substitution, DESIGN.md §1).
pub const OMNI_CLIP_GRID: [f32; 9] = [1.0, 0.975, 0.95, 0.925, 0.9, 0.875, 0.85, 0.8, 0.75];

#[inline]
fn round_half_up(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// Fake-quantize one group slice with range shrunk by `ratio`, writing into
/// `out`; returns the squared reconstruction error.
fn fake_quant_group_clipped(seg: &[f32], out: &mut [f32], qmax: f32, ratio: f32) -> f64 {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &v in seg {
        mn = mn.min(v);
        mx = mx.max(v);
    }
    let mid = 0.5 * (mn + mx);
    let half = 0.5 * (mx - mn) * ratio;
    let (cmn, cmx) = (mid - half, mid + half);
    let range = cmx - cmn;
    let scale = if range > 0.0 { range / qmax } else { 1.0 };
    // same packable-zero clamp as the plain codec (quant::group)
    let zero = round_half_up(-cmn / scale).clamp(0.0, qmax);
    let mut err = 0.0f64;
    for (o, &v) in out.iter_mut().zip(seg) {
        let q = (round_half_up(v / scale) + zero).clamp(0.0, qmax);
        *o = scale * (q - zero);
        let d = (*o - v) as f64;
        err += d * d;
    }
    err
}

/// Fake-quantize with per-group clip-ratio *search* over `grid`, picking the
/// ratio minimizing group MSE.  This is the quantizer semantics behind the
/// AWQ and OmniQuant rows (after their respective scaling preprocessing).
pub fn fake_quant_clip_search(w: &Tensor, scheme: QuantScheme, grid: &[f32]) -> Tensor {
    let (rows, cols) = w.shape();
    assert_eq!(cols % scheme.group, 0);
    let qmax = scheme.qmax();
    let mut out = Tensor::zeros(rows, cols);
    let mut best = vec![0.0f32; scheme.group];
    let mut cand = vec![0.0f32; scheme.group];
    for r in 0..rows {
        for g in 0..cols / scheme.group {
            let a = g * scheme.group;
            let seg = &w.row(r)[a..a + scheme.group];
            let mut best_err = f64::INFINITY;
            for &ratio in grid {
                let err = fake_quant_group_clipped(seg, &mut cand, qmax, ratio);
                if err < best_err {
                    best_err = err;
                    best.copy_from_slice(&cand);
                }
            }
            out.row_mut(r)[a..a + scheme.group].copy_from_slice(&best);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::fake_quant;
    use crate::util::{propcheck, rng::Pcg64};

    fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize) -> Tensor {
        Tensor::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn clip_search_never_worse_than_plain() {
        propcheck::check("clip-search MSE <= RTN MSE", 32, |rng| {
            let scheme = QuantScheme::new(2, 32);
            let w = rand_tensor(rng, 4, 64);
            let plain = fake_quant(&w, scheme);
            let clipped = fake_quant_clip_search(&w, scheme, &AWQ_CLIP_GRID);
            let e_plain = w.mse(&plain);
            let e_clip = w.mse(&clipped);
            propcheck::ensure(
                e_clip <= e_plain + 1e-12,
                format!("clip {e_clip} > plain {e_plain}"),
            )
        });
    }

    #[test]
    fn ratio_one_equals_plain_rtn() {
        let mut rng = Pcg64::new(1);
        let scheme = QuantScheme::new(3, 32);
        let w = rand_tensor(&mut rng, 4, 64);
        let a = fake_quant(&w, scheme);
        let b = fake_quant_clip_search(&w, scheme, &[1.0]);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn clipping_helps_with_outliers() {
        // a single huge outlier: clipping its group should win vs RTN
        let mut rng = Pcg64::new(2);
        let scheme = QuantScheme::new(2, 32);
        let mut w = rand_tensor(&mut rng, 1, 32);
        for v in w.data.iter_mut() {
            *v *= 0.05;
        }
        w.data[7] = 5.0;
        let plain_err = w.mse(&fake_quant(&w, scheme));
        let clip_err = w.mse(&fake_quant_clip_search(&w, scheme, &OMNI_CLIP_GRID));
        assert!(clip_err < plain_err, "clip {clip_err} vs plain {plain_err}");
    }

    #[test]
    fn finer_grid_never_worse() {
        let mut rng = Pcg64::new(3);
        let scheme = QuantScheme::new(2, 32);
        let w = rand_tensor(&mut rng, 8, 64);
        let coarse = w.mse(&fake_quant_clip_search(&w, scheme, &AWQ_CLIP_GRID));
        let fine = w.mse(&fake_quant_clip_search(&w, scheme, &OMNI_CLIP_GRID));
        // OMNI grid is a superset of ratios 1.0/0.95/... except 0.85 etc —
        // not strictly nested, but must be at least close:
        assert!(fine <= coarse * 1.02);
    }
}

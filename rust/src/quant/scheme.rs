//! Quantization scheme descriptors: the uniform [`QuantScheme`] and the
//! mixed-precision [`BitAllocation`] (per-tensor schemes under a global
//! bits/param budget — BiLLM/PTQ1.61-style heterogeneous precision).

use crate::model::config::{split_layer_prefix, LAYER_QUANT_NAMES};
use crate::model::OptConfig;

/// Bits + group size for asymmetric unsigned integer group quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantScheme {
    /// Integer width in bits (1..=8).
    pub bits: usize,
    /// Channels sharing one scale/zero pair.
    pub group: usize,
}

impl QuantScheme {
    /// A `bits`-bit scheme with `group`-channel scale groups; panics on
    /// bits outside 1..=8 or a zero group (CLI input goes through
    /// [`QuantScheme::parse`], which returns errors instead).
    pub fn new(bits: usize, group: usize) -> QuantScheme {
        assert!((1..=8).contains(&bits), "bits must be 1..=8");
        assert!(group > 0, "group must be positive");
        QuantScheme { bits, group }
    }

    /// Largest representable code (q_min is always 0).
    pub fn qmax(&self) -> f32 {
        ((1usize << self.bits) - 1) as f32
    }

    /// Effective bits per parameter including FP16 scale + zero-point
    /// overhead per group (the paper's Table-3 "Bits/Param" column:
    /// bits + 16/group for scale; the integer zero-point costs `bits`).
    pub fn bits_per_param(&self) -> f64 {
        self.bits as f64 + (16.0 + self.bits as f64) / self.group as f64
    }

    /// Parse "2x64" / "3b128"-style strings from the CLI.
    ///
    /// The whole string must be consumed: `"2x64x32"` is rejected (the old
    /// parser's `split_once` left the tail inside the group field, which a
    /// strict integer parse now surfaces as an explicit trailing-garbage
    /// error instead of an opaque `ParseIntError`).
    ///
    /// ```
    /// use invarexplore::quant::QuantScheme;
    ///
    /// let s = QuantScheme::parse("2x64")?;
    /// assert_eq!((s.bits, s.group), (2, 64));
    /// assert_eq!(QuantScheme::parse("3b128")?, QuantScheme::new(3, 128));
    ///
    /// assert!(QuantScheme::parse("2x64x32").is_err()); // trailing garbage
    /// assert!(QuantScheme::parse("9x64").is_err()); // bits outside 1..=8
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn parse(s: &str) -> crate::Result<QuantScheme> {
        let (b, g) = s
            .split_once(['x', 'b'])
            .ok_or_else(|| anyhow::anyhow!("bad quant scheme {s:?} (want e.g. 2x64)"))?;
        let bits: usize = b
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad bits {b:?} in quant scheme {s:?} (want e.g. 2x64)"))?;
        let group: usize = g.trim().parse().map_err(|_| {
            anyhow::anyhow!(
                "bad group {g:?} in quant scheme {s:?}: the group must be a plain \
                 integer with nothing after it (want e.g. 2x64)"
            )
        })?;
        anyhow::ensure!(
            (1..=8).contains(&bits),
            "quant scheme {s:?}: bits {bits} outside 1..=8"
        );
        anyhow::ensure!(group > 0, "quant scheme {s:?}: group must be positive");
        Ok(QuantScheme { bits, group })
    }

    /// Canonical `"<bits>x<group>"` form, re-parseable by
    /// [`QuantScheme::parse`].
    pub fn label(&self) -> String {
        format!("{}x{}", self.bits, self.group)
    }
}

impl std::fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-bit g{}", self.bits, self.group)
    }
}

/// Normalize an override selector to a canonical tensor selector: either a
/// layer-agnostic base name (`up.w`) or a full parameter name (`l3.up.w`).
/// Friendly aliases (`ffn_up`, `attn_q`, …) map to base names.  Anything
/// that is not a quantizable linear is rejected — the "unknown tensor"
/// parse-error path.
fn normalize_selector(sel: &str) -> crate::Result<String> {
    let aliased = match sel {
        "ffn_up" => "up.w",
        "ffn_down" => "down.w",
        "attn_q" => "q.w",
        "attn_k" => "k.w",
        "attn_v" => "v.w",
        "attn_o" => "o.w",
        other => other,
    };
    let (_, base) = split_layer_prefix(aliased);
    anyhow::ensure!(
        LAYER_QUANT_NAMES.contains(&base),
        "unknown tensor {sel:?} in bit allocation (quantizable: q.w|k.w|v.w|o.w|up.w|down.w, \
         optionally l<i>-prefixed like l0.up.w; aliases attn_q|attn_k|attn_v|attn_o|ffn_up|ffn_down)"
    );
    Ok(aliased.to_string())
}

/// Mixed-precision bit allocation: a default [`QuantScheme`] plus per-tensor
/// overrides, e.g. `"2x64,ffn_up=3x64,l0.q.w=4x128"`.
///
/// Selector precedence at lookup time: an exact full-name override
/// (`l0.up.w`) wins over a layer-agnostic base-name override (`up.w`),
/// which wins over the default.  The global budget of an allocation is its
/// size-weighted mean [`QuantScheme::bits_per_param`] over a model's
/// quantizable tensors ([`BitAllocation::bits_per_param`]); the bit-swap
/// search move in `search::alloc` only ever proposes allocations at or
/// under that budget.
#[derive(Debug, Clone, PartialEq)]
pub struct BitAllocation {
    /// Scheme for every tensor without an override.
    pub default: QuantScheme,
    /// Normalized `(selector, scheme)` overrides in precedence-irrelevant
    /// storage order (duplicates are rejected at parse time).
    pub overrides: Vec<(String, QuantScheme)>,
}

impl BitAllocation {
    /// The allocation every tensor shares: the pre-mixed-precision world.
    pub fn uniform(default: QuantScheme) -> BitAllocation {
        BitAllocation { default, overrides: Vec::new() }
    }

    /// Parse `"<default>[,<selector>=<scheme>]*"`, e.g.
    /// `"2x64,ffn_up=3x64,l0.q.w=4x128"`.  A bare scheme (`"2x64"`) parses
    /// as a uniform allocation.
    ///
    /// ```
    /// use invarexplore::quant::{BitAllocation, QuantScheme};
    ///
    /// let a = BitAllocation::parse("2x64,ffn_up=3x64,l0.q.w=4x128")?;
    /// assert_eq!(a.default, QuantScheme::new(2, 64));
    /// // aliases normalize to base tensor names
    /// assert!(a.overrides.iter().any(|(sel, sch)| sel == "up.w" && *sch == QuantScheme::new(3, 64)));
    ///
    /// assert_eq!(BitAllocation::parse("2x64")?, BitAllocation::uniform(QuantScheme::new(2, 64)));
    ///
    /// assert!(BitAllocation::parse("2x64,bogus=3x64").is_err()); // unknown tensor
    /// assert!(BitAllocation::parse("2x64,ffn_up=3x64,ffn_up=1x64").is_err()); // duplicate
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn parse(s: &str) -> crate::Result<BitAllocation> {
        let mut parts = s.split(',');
        let head = parts.next().unwrap_or("");
        anyhow::ensure!(
            !head.trim().is_empty(),
            "empty bit allocation (want e.g. \"2x64,ffn_up=3x64\")"
        );
        anyhow::ensure!(
            !head.contains('='),
            "bit allocation {s:?} must start with the default scheme (e.g. \"2x64\"), \
             not an override"
        );
        let default = QuantScheme::parse(head.trim())?;
        let mut overrides: Vec<(String, QuantScheme)> = Vec::new();
        for part in parts {
            let part = part.trim();
            anyhow::ensure!(
                !part.is_empty(),
                "empty override entry in bit allocation {s:?} (trailing or doubled comma?)"
            );
            let (sel, scheme) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("bad override {part:?} in bit allocation {s:?} (want name=scheme)")
            })?;
            let sel = normalize_selector(sel.trim())?;
            anyhow::ensure!(
                overrides.iter().all(|(existing, _)| existing != &sel),
                "duplicate tensor {sel:?} in bit allocation {s:?}"
            );
            overrides.push((sel, QuantScheme::parse(scheme.trim())?));
        }
        Ok(BitAllocation { default, overrides })
    }

    /// No overrides — every tensor uses the default scheme.
    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty()
    }

    /// Scheme of one tensor.  `name` is a canonical parameter name
    /// (`l0.up.w`); exact overrides beat base-name overrides beat default.
    pub fn scheme_for(&self, name: &str) -> QuantScheme {
        if let Some((_, s)) = self.overrides.iter().find(|(sel, _)| sel == name) {
            return *s;
        }
        let (_, base) = split_layer_prefix(name);
        self.overrides
            .iter()
            .find(|(sel, _)| sel == base)
            .map(|(_, s)| *s)
            .unwrap_or(self.default)
    }

    /// Insert or replace an exact per-tensor override (the bit-swap commit
    /// path writes searched schemes back through this).
    pub fn set_scheme(&mut self, name: &str, scheme: QuantScheme) {
        if let Some(entry) = self.overrides.iter_mut().find(|(sel, _)| sel == name) {
            entry.1 = scheme;
        } else {
            self.overrides.push((name.to_string(), scheme));
        }
    }

    /// Global budget accounting: the size-weighted mean
    /// [`QuantScheme::bits_per_param`] over every quantizable tensor of
    /// `cfg` — the honest "Bits/Param" of the heterogeneous model.
    pub fn bits_per_param(&self, cfg: &OptConfig) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for name in cfg.quant_names() {
            let (r, c) = cfg.param_shape(&name).expect("quant names are known params");
            let numel = (r * c) as f64;
            num += numel * self.scheme_for(&name).bits_per_param();
            den += numel;
        }
        num / den.max(1.0)
    }

    /// Check this allocation against a concrete model: every tensor's group
    /// must divide its column count (the group codec's precondition), and
    /// every exact `l<i>.`-prefixed override must name a layer that exists
    /// — a phantom `l12.q.w` on a 12-layer model (layers 0..=11) would
    /// otherwise parse cleanly and then silently never apply.
    pub fn validate(&self, cfg: &OptConfig) -> crate::Result<()> {
        for (sel, _) in &self.overrides {
            if let (Some(l), _) = split_layer_prefix(sel) {
                anyhow::ensure!(
                    l < cfg.n_layers,
                    "bit allocation: override {sel:?} names layer {l}, but {} has \
                     only {} layers (l0..=l{})",
                    cfg.name,
                    cfg.n_layers,
                    cfg.n_layers.saturating_sub(1)
                );
            }
        }
        for name in cfg.quant_names() {
            let s = self.scheme_for(&name);
            let (_, c) = cfg.param_shape(&name)?;
            anyhow::ensure!(
                c % s.group == 0,
                "bit allocation: {name} has {c} columns, not divisible by group {}",
                s.group
            );
        }
        Ok(())
    }

    /// Canonical round-trippable form: `default[,sel=scheme]*`.
    pub fn label(&self) -> String {
        let mut out = self.default.label();
        for (sel, s) in &self.overrides {
            out.push(',');
            out.push_str(sel);
            out.push('=');
            out.push_str(&s.label());
        }
        out
    }
}

impl std::fmt::Display for BitAllocation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(QuantScheme::new(1, 64).qmax(), 1.0);
        assert_eq!(QuantScheme::new(2, 64).qmax(), 3.0);
        assert_eq!(QuantScheme::new(8, 64).qmax(), 255.0);
    }

    #[test]
    fn bits_per_param_matches_paper_shape() {
        // paper Table 3: 2-bit g128 -> 2.125; our formula adds the int zero
        // point too (2 + 18/128 ≈ 2.14) — same ballpark, monotone in group.
        let g64 = QuantScheme::new(2, 64).bits_per_param();
        let g128 = QuantScheme::new(2, 128).bits_per_param();
        assert!(g64 > g128);
        assert!((g128 - 2.14).abs() < 0.01);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(QuantScheme::parse("2x64").unwrap(), QuantScheme::new(2, 64));
        assert_eq!(QuantScheme::parse("3b32").unwrap(), QuantScheme::new(3, 32));
        assert!(QuantScheme::parse("junk").is_err());
    }

    #[test]
    fn parse_rejects_trailing_garbage() {
        // REGRESSION: "2x64x32" used to die inside the group integer parse
        // with a bare ParseIntError; it must be rejected with a message that
        // names the offending tail.
        let err = QuantScheme::parse("2x64x32").unwrap_err().to_string();
        assert!(err.contains("64x32"), "unhelpful error: {err}");
        assert!(QuantScheme::parse("2x64 extra").is_err());
        assert!(QuantScheme::parse("2x").is_err());
        assert!(QuantScheme::parse("x64").is_err());
    }

    #[test]
    fn parse_rejects_out_of_range_without_panicking() {
        // CLI input must produce Err, not the constructor assert
        assert!(QuantScheme::parse("0x64").is_err());
        assert!(QuantScheme::parse("9x64").is_err());
        assert!(QuantScheme::parse("2x0").is_err());
    }

    #[test]
    #[should_panic]
    fn zero_bits_rejected() {
        QuantScheme::new(0, 64);
    }

    // ---- BitAllocation ----------------------------------------------------

    #[test]
    fn allocation_parse_and_lookup() {
        let a = BitAllocation::parse("2x64,ffn_up=3x64,l0.q.w=4x128").unwrap();
        assert_eq!(a.default, QuantScheme::new(2, 64));
        // alias normalizes to the base name and applies to every layer
        assert_eq!(a.scheme_for("l0.up.w"), QuantScheme::new(3, 64));
        assert_eq!(a.scheme_for("l7.up.w"), QuantScheme::new(3, 64));
        // exact override beats the default
        assert_eq!(a.scheme_for("l0.q.w"), QuantScheme::new(4, 128));
        // other layers' q.w fall back to the default
        assert_eq!(a.scheme_for("l1.q.w"), QuantScheme::new(2, 64));
        assert_eq!(a.scheme_for("l0.down.w"), QuantScheme::new(2, 64));
    }

    #[test]
    fn exact_override_beats_base_override() {
        let a = BitAllocation::parse("2x64,up.w=3x64,l1.up.w=1x64").unwrap();
        assert_eq!(a.scheme_for("l0.up.w"), QuantScheme::new(3, 64));
        assert_eq!(a.scheme_for("l1.up.w"), QuantScheme::new(1, 64));
    }

    #[test]
    fn allocation_error_paths() {
        // empty allocation / empty override entry
        assert!(BitAllocation::parse("").is_err());
        assert!(BitAllocation::parse("2x64,").is_err());
        assert!(BitAllocation::parse("2x64,,ffn_up=3x64").is_err());
        // must start with a default scheme, not an override
        assert!(BitAllocation::parse("ffn_up=3x64").is_err());
        // duplicate tensor (also via alias collision)
        assert!(BitAllocation::parse("2x64,up.w=3x64,up.w=4x64").is_err());
        assert!(BitAllocation::parse("2x64,ffn_up=3x64,up.w=4x64").is_err());
        // bits outside 1..=8
        assert!(BitAllocation::parse("2x64,ffn_up=9x64").is_err());
        assert!(BitAllocation::parse("2x64,ffn_up=0x64").is_err());
        // unknown tensor
        let err = BitAllocation::parse("2x64,lm_head=4x128").unwrap_err().to_string();
        assert!(err.contains("unknown tensor"), "{err}");
        // override missing '='
        assert!(BitAllocation::parse("2x64,ffn_up").is_err());
    }

    #[test]
    fn budget_is_size_weighted_mean() {
        let cfg = OptConfig::test_config(); // d=32, f=64: qkvo 32x32, up 64x32, down 32x64
        let uniform = BitAllocation::uniform(QuantScheme::new(2, 32));
        let per_tensor = QuantScheme::new(2, 32).bits_per_param();
        assert!((uniform.bits_per_param(&cfg) - per_tensor).abs() < 1e-12);

        let mixed = BitAllocation::parse("2x32,ffn_up=4x32").unwrap();
        // hand-computed size-weighted mean over one layer's tensors
        // (identical per layer, so one layer's mean == the model mean)
        let qkvo = 4.0 * (32.0 * 32.0);
        let up = 64.0 * 32.0;
        let down = 32.0 * 64.0;
        let expect = (qkvo * QuantScheme::new(2, 32).bits_per_param()
            + up * QuantScheme::new(4, 32).bits_per_param()
            + down * QuantScheme::new(2, 32).bits_per_param())
            / (qkvo + up + down);
        assert!((mixed.bits_per_param(&cfg) - expect).abs() < 1e-12);
        assert!(mixed.bits_per_param(&cfg) > uniform.bits_per_param(&cfg));
    }

    #[test]
    fn label_roundtrips() {
        for s in ["2x64", "2x64,up.w=3x64,l0.q.w=4x128"] {
            let a = BitAllocation::parse(s).unwrap();
            let b = BitAllocation::parse(&a.label()).unwrap();
            assert_eq!(a, b, "{s}");
        }
    }

    #[test]
    fn set_scheme_inserts_and_replaces() {
        let mut a = BitAllocation::uniform(QuantScheme::new(2, 32));
        a.set_scheme("l0.up.w", QuantScheme::new(3, 32));
        assert_eq!(a.scheme_for("l0.up.w"), QuantScheme::new(3, 32));
        a.set_scheme("l0.up.w", QuantScheme::new(4, 32));
        assert_eq!(a.scheme_for("l0.up.w"), QuantScheme::new(4, 32));
        assert_eq!(a.overrides.len(), 1);
    }

    #[test]
    fn validate_checks_group_divisibility() {
        let cfg = OptConfig::test_config(); // all cols are 32 or 64
        assert!(BitAllocation::parse("2x32").unwrap().validate(&cfg).is_ok());
        // group 64 does not divide the 32-column attention tensors
        assert!(BitAllocation::parse("2x64").unwrap().validate(&cfg).is_err());
        assert!(BitAllocation::parse("2x32,ffn_down=2x64")
            .unwrap()
            .validate(&cfg)
            .is_ok()); // down.w has 64 cols
    }

    #[test]
    fn validate_rejects_phantom_layer_overrides() {
        // test_config has 2 layers (l0, l1): an l2 override would be
        // silently inert — validate must reject it loudly
        let cfg = OptConfig::test_config();
        assert!(BitAllocation::parse("2x32,l1.q.w=4x32").unwrap().validate(&cfg).is_ok());
        let err = BitAllocation::parse("2x32,l2.q.w=4x32")
            .unwrap()
            .validate(&cfg)
            .unwrap_err();
        assert!(err.to_string().contains("only 2 layers"), "{err}");
    }
}

//! Quantization scheme descriptor.

/// Bits + group size for asymmetric unsigned integer group quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantScheme {
    pub bits: usize,
    pub group: usize,
}

impl QuantScheme {
    pub fn new(bits: usize, group: usize) -> QuantScheme {
        assert!((1..=8).contains(&bits), "bits must be 1..=8");
        assert!(group > 0, "group must be positive");
        QuantScheme { bits, group }
    }

    /// Largest representable code (q_min is always 0).
    pub fn qmax(&self) -> f32 {
        ((1usize << self.bits) - 1) as f32
    }

    /// Effective bits per parameter including FP16 scale + zero-point
    /// overhead per group (the paper's Table-3 "Bits/Param" column:
    /// bits + 16/group for scale; the integer zero-point costs `bits`).
    pub fn bits_per_param(&self) -> f64 {
        self.bits as f64 + (16.0 + self.bits as f64) / self.group as f64
    }

    /// Parse "2x64" / "3b128"-style strings from the CLI.
    pub fn parse(s: &str) -> crate::Result<QuantScheme> {
        let (b, g) = s
            .split_once(['x', 'b'])
            .ok_or_else(|| anyhow::anyhow!("bad quant scheme {s:?} (want e.g. 2x64)"))?;
        Ok(QuantScheme::new(b.trim().parse()?, g.trim().parse()?))
    }

    pub fn label(&self) -> String {
        format!("{}x{}", self.bits, self.group)
    }
}

impl std::fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-bit g{}", self.bits, self.group)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(QuantScheme::new(1, 64).qmax(), 1.0);
        assert_eq!(QuantScheme::new(2, 64).qmax(), 3.0);
        assert_eq!(QuantScheme::new(8, 64).qmax(), 255.0);
    }

    #[test]
    fn bits_per_param_matches_paper_shape() {
        // paper Table 3: 2-bit g128 -> 2.125; our formula adds the int zero
        // point too (2 + 18/128 ≈ 2.14) — same ballpark, monotone in group.
        let g64 = QuantScheme::new(2, 64).bits_per_param();
        let g128 = QuantScheme::new(2, 128).bits_per_param();
        assert!(g64 > g128);
        assert!((g128 - 2.14).abs() < 0.01);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(QuantScheme::parse("2x64").unwrap(), QuantScheme::new(2, 64));
        assert_eq!(QuantScheme::parse("3b32").unwrap(), QuantScheme::new(3, 32));
        assert!(QuantScheme::parse("junk").is_err());
    }

    #[test]
    #[should_panic]
    fn zero_bits_rejected() {
        QuantScheme::new(0, 64);
    }
}

//! Groupwise asymmetric integer quantization (Eqns. 1–4): the Rust codec
//! mirrors the Pallas kernel / jnp oracle bit-for-bit (shared conventions
//! documented in `python/compile/kernels/ref.py`), plus packed int storage
//! with bits/param accounting for the Table-3 memory columns.

/// Weight-clipping search minimizing groupwise quantization MSE.
pub mod clip;
/// The groupwise codec: quantize / dequantize / fake-quant.
pub mod group;
/// Bit-packed deployment form and its fused dequant-GEMM kernels.
pub mod packed;
/// Scheme descriptors: [`QuantScheme`] and [`BitAllocation`].
pub mod scheme;
/// Runtime SIMD dispatch (scalar / SSE2 / AVX2), bit-identical tiers.
pub mod simd;

pub use group::{dequantize, fake_quant, fake_quant_into, quant_mse, quantize, GroupQuant};
pub use packed::PackedTensor;
pub use scheme::{BitAllocation, QuantScheme};
pub use simd::{set_simd_level, SimdLevel};

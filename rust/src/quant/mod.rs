//! Groupwise asymmetric integer quantization (Eqns. 1–4): the Rust codec
//! mirrors the Pallas kernel / jnp oracle bit-for-bit (shared conventions
//! documented in `python/compile/kernels/ref.py`), plus packed int storage
//! with bits/param accounting for the Table-3 memory columns.

pub mod clip;
pub mod group;
pub mod packed;
pub mod scheme;
pub mod simd;

pub use group::{dequantize, fake_quant, fake_quant_into, quant_mse, quantize, GroupQuant};
pub use packed::PackedTensor;
pub use scheme::{BitAllocation, QuantScheme};
pub use simd::{set_simd_level, SimdLevel};

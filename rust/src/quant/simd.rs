//! Runtime SIMD dispatch for the packed serving kernels.
//!
//! x86-64 only: SSE2 is part of the architecture baseline (always present),
//! AVX2 is probed once with `is_x86_feature_detected!`.  Every vector path
//! is written to be **bit-identical** to its scalar fallback — explicit
//! `mul` + `add` intrinsics (no FMA contraction) with per-output-element
//! accumulation order unchanged — so the dispatch level never changes
//! results, only speed (pinned by the scalar-vs-SIMD identity tests in
//! `quant::packed`).
//!
//! Override order: an explicit [`set_simd_level`] call (tests, the kernel
//! microbench's in-process A/B comparison) beats the `INVAREXPLORE_SIMD`
//! env value (`scalar` | `sse2` | `avx2`), which beats hardware detection.
//! Requesting a level the CPU lacks falls back to the best supported one.
//! The resolved level is logged once at first use.

use std::sync::atomic::{AtomicU8, Ordering};

/// Vector ISA tier the packed kernels run at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Portable scalar loops — the reference every other tier must match.
    Scalar = 0,
    /// 4-lane f32: the fused GEMM tile kernel only (SSE2 has no variable
    /// shift or gather, so dequant stays scalar at this tier).
    Sse2 = 1,
    /// 8-lane f32: vectorized code unpack + dequant (bits ≤ 4) and the
    /// 8-wide GEMM tile kernel.
    Avx2 = 2,
}

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn decode(v: u8) -> SimdLevel {
    match v {
        2 => SimdLevel::Avx2,
        1 => SimdLevel::Sse2,
        _ => SimdLevel::Scalar,
    }
}

/// Best level this CPU supports.
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            SimdLevel::Avx2
        } else {
            SimdLevel::Sse2
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        SimdLevel::Scalar
    }
}

/// The active dispatch level, resolving (and logging) it on first use.
#[inline]
pub fn level() -> SimdLevel {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return decode(v);
    }
    init()
}

#[cold]
fn init() -> SimdLevel {
    let hw = detect();
    let lvl = match std::env::var("INVAREXPLORE_SIMD").as_deref() {
        Ok("scalar") => SimdLevel::Scalar,
        Ok("sse2") => SimdLevel::Sse2.min(hw),
        Ok("avx2") => SimdLevel::Avx2.min(hw),
        Ok(other) => {
            crate::warn_!("INVAREXPLORE_SIMD={other:?} not recognized; using detected level");
            hw
        }
        Err(_) => hw,
    };
    // racing first calls may both log; harmless (same line) and lock-free
    crate::info!("simd dispatch: {lvl:?} (detected {hw:?})");
    // CLAMPED: SimdLevel discriminants are 0..=2, well inside u8.
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    lvl
}

/// Force a dispatch level (clamped to hardware support) — the in-process
/// hook the bit-identity tests and `benches/kernel_microbench.rs` use to
/// compare tiers without mutating the environment (see the getenv/setenv
/// UB note in `util::pool`'s tests).
pub fn set_simd_level(lvl: SimdLevel) {
    // CLAMPED: SimdLevel discriminants are 0..=2, well inside u8.
    LEVEL.store(lvl.min(detect()) as u8, Ordering::Relaxed);
}

/// Serializes tests that flip the global dispatch level, so two A/B
/// comparisons can't interleave their level switches.  (Every tier is
/// bit-identical, so a race would not change results — this just keeps
/// each test's "scalar" leg honestly scalar.)
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_level_clamps_to_hardware() {
        let _g = test_guard();
        let prev = level();
        set_simd_level(SimdLevel::Avx2);
        assert!(level() <= detect());
        set_simd_level(SimdLevel::Scalar);
        assert_eq!(level(), SimdLevel::Scalar);
        set_simd_level(prev); // restore for concurrently-running tests
    }

    #[test]
    fn detect_is_stable() {
        assert_eq!(detect(), detect());
        #[cfg(target_arch = "x86_64")]
        assert!(detect() >= SimdLevel::Sse2, "SSE2 is the x86-64 baseline");
    }
}

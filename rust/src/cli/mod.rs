//! CLI subcommands of the `invarexplore` binary.

use crate::baselines::Method;
use crate::coordinator::{pipeline, tables, PipelineOpts, Session};
use crate::quant::QuantScheme;
use crate::transform::TransformKinds;
use crate::util::cli::{parse_args, usage, ArgSpec, Args};

pub const USAGE: &str = "\
invarexplore — InvarExplore: discrete search over model invariance for
ultra-low-bit quantization (paper reproduction).

usage: invarexplore <command> [options]

commands:
  info             show artifacts manifest summary
  eval-ppl         perplexity of a (quantized) model on a corpus
  eval-reasoning   few-shot reasoning accuracy
  quantize         quantize with a baseline method, report quality + memory
  search           run the InvarExplore search on top of a baseline
  apply            materialize searched transforms into an .iwt weight file
  serve            drive the continuous-batching scheduler from packed weights
  table1..table5   regenerate the paper's tables (also: cargo bench)
  figure1          regenerate the paper's optimization-curve figure

common options: --model, --method, --scheme (e.g. 2x64), --steps, --seed,
--batch (K-wide concurrent proposal rounds; 1 = exact sequential search),
--alloc (mixed-precision allocation, e.g. 2x64,ffn_up=3x64,l0.q.w=4x128),
--alloc-prob (probability a proposal is a budget-preserving bit swap),
--spec (self-speculative draft length for `serve`; env SERVE_SPEC),
--kv-dtype (KV-cache storage f32|int8|int4 for `serve`; env SERVE_KV_DTYPE),
--replicas / --shards / --shed-watermark (multi-replica routing and
tensor-parallel sharding for `serve`; envs SERVE_REPLICAS, SERVE_SHARDS,
SERVE_SHED_WATERMARK — see README \"Sharded serving\"),
--fault-plan / --round-budget-ms / --drain (fault injection, per-round
wall-clock budget and graceful drain for `serve`; envs SERVE_FAULT_PLAN,
SERVE_ROUND_BUDGET_MS — see README \"Fault tolerance\")
run `invarexplore <command> --help` for details.
";

fn common_spec() -> Vec<ArgSpec> {
    vec![
        ArgSpec { name: "model", help: "model size (opt-tiny|opt-small|opt-base)", default: Some("opt-small"), is_flag: false },
        ArgSpec { name: "method", help: "baseline method (rtn|gptq|awq|omniquant)", default: Some("awq"), is_flag: false },
        ArgSpec { name: "scheme", help: "quantization scheme bits x group, e.g. 1x64", default: Some("1x64"), is_flag: false },
        ArgSpec { name: "alloc", help: "mixed-precision bit allocation, e.g. 2x64,ffn_up=3x64 (overrides --scheme)", default: None, is_flag: false },
        ArgSpec { name: "alloc-prob", help: "probability a search proposal is a bit-swap allocation move (default: $INVAREXPLORE_P_ALLOC or 0)", default: None, is_flag: false },
        ArgSpec { name: "steps", help: "search steps", default: Some("200"), is_flag: false },
        ArgSpec { name: "batch", help: "proposals per search round (1 = exact sequential semantics)", default: Some("1"), is_flag: false },
        ArgSpec { name: "kinds", help: "transform kinds subset of psr", default: Some("psr"), is_flag: false },
        ArgSpec { name: "match-layers", help: "activation-matching layer count", default: Some("2"), is_flag: false },
        ArgSpec { name: "calib-seqs", help: "calibration sequences", default: Some("32"), is_flag: false },
        ArgSpec { name: "eval-seqs", help: "ppl eval sequences", default: Some("64"), is_flag: false },
        ArgSpec { name: "reasoning-n", help: "reasoning examples per task (0=skip)", default: Some("0"), is_flag: false },
        ArgSpec { name: "shots", help: "few-shot demonstrations", default: Some("5"), is_flag: false },
        ArgSpec { name: "seed", help: "RNG seed", default: Some("0"), is_flag: false },
        ArgSpec { name: "corpus", help: "eval corpus (wiki|c4|pile)", default: Some("wiki"), is_flag: false },
        ArgSpec { name: "out", help: "output path (state json / weights iwt)", default: None, is_flag: false },
        ArgSpec { name: "csv", help: "telemetry CSV output path", default: None, is_flag: false },
        ArgSpec { name: "resume", help: "resume search from a state.json checkpoint", default: None, is_flag: false },
        ArgSpec { name: "spec", help: "serve: draft tokens per speculative round (0 = off; default: $SERVE_SPEC or 0)", default: None, is_flag: false },
        ArgSpec { name: "draft-alloc", help: "serve: draft-model bit allocation (default: $SERVE_DRAFT_ALLOC, else the cheapest manifest preset under the target's budget)", default: None, is_flag: false },
        ArgSpec { name: "policy", help: "serve: admission policy fcfs|spf|edf (default: $SERVE_POLICY or fcfs)", default: None, is_flag: false },
        ArgSpec { name: "kv-dtype", help: "serve: KV-cache storage f32|int8|int4 (default: $SERVE_KV_DTYPE or f32; f32 is bit-identical, int8/int4 trade a documented error bound for ~3.6x/~6.4x lower KV residency)", default: None, is_flag: false },
        ArgSpec { name: "sampler", help: "serve: decoding sampler greedy|temp:<t>|topk:<k>[:<t>] (default: $SERVE_SAMPLER or greedy)", default: None, is_flag: false },
        ArgSpec { name: "requests", help: "serve: synthetic requests to submit", default: Some("8"), is_flag: false },
        ArgSpec { name: "max-new", help: "serve: tokens to generate per request", default: Some("24"), is_flag: false },
        ArgSpec { name: "max-batch", help: "serve: concurrent decode slots", default: Some("4"), is_flag: false },
        ArgSpec { name: "replicas", help: "serve: scheduler replicas behind the prefix-affinity router (default: $SERVE_REPLICAS or 1)", default: None, is_flag: false },
        ArgSpec { name: "shards", help: "serve: tensor-parallel row shards of the packed model, bit-identical at any count (default: $SERVE_SHARDS or 1)", default: None, is_flag: false },
        ArgSpec { name: "shed-watermark", help: "serve: per-replica queued-request watermark past which no-deadline requests are shed; 0 = never shed (default: $SERVE_SHED_WATERMARK or 0)", default: None, is_flag: false },
        ArgSpec { name: "fault-plan", help: "serve: deterministic fault-injection spec, e.g. seed=42,kill=1@3,transient=0.05,stall=7@2x40 (default: $SERVE_FAULT_PLAN or none)", default: None, is_flag: false },
        ArgSpec { name: "round-budget-ms", help: "serve: per-round wall-clock budget in ms; a slot whose decode round blows it finishes Failed; 0 = unbounded (default: $SERVE_ROUND_BUDGET_MS or 0)", default: None, is_flag: false },
        ArgSpec { name: "drain", help: "serve: graceful drain — stop admission after submitting the synthetic traffic, finish in-flight work and print the drain summary", default: None, is_flag: true },
        ArgSpec { name: "trace-out", help: "write a Chrome trace (chrome://tracing JSON) of this run to PATH and print Prometheus metrics (default: $INVAREXPLORE_TRACE=PATH)", default: None, is_flag: false },
        ArgSpec { name: "help", help: "show options", default: None, is_flag: true },
    ]
}

fn opts_from_args(a: &Args) -> crate::Result<PipelineOpts> {
    let method = Method::parse(a.get_or("method", "awq"))?;
    let alloc = a.get("alloc").map(crate::quant::BitAllocation::parse).transpose()?;
    // --alloc's default scheme doubles as --scheme so budget accounting and
    // reports stay consistent
    let scheme = match &alloc {
        Some(al) => al.default,
        None => QuantScheme::parse(a.get_or("scheme", "1x64"))?,
    };
    let mut opts = PipelineOpts::new(a.get_or("model", "opt-small"), method, scheme);
    opts.alloc = alloc;
    // --alloc-prob wins; otherwise the documented env knob is honored
    opts.p_alloc = match a.get("alloc-prob") {
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| anyhow::anyhow!("bad --alloc-prob {v:?} (want a probability)"))?,
        None => crate::util::cli::env_override("INVAREXPLORE_P_ALLOC", 0.0f64),
    }
    .clamp(0.0, 1.0);
    opts.steps = a.parse_or("steps", 200usize)?;
    opts.batch = a.parse_or("batch", 1usize)?.max(1);
    opts.kinds = TransformKinds::parse(a.get_or("kinds", "psr"))?;
    opts.match_layers = a.parse_or("match-layers", 2usize)?;
    opts.calib_seqs = a.parse_or("calib-seqs", 32usize)?;
    opts.eval_seqs = a.parse_or("eval-seqs", 64usize)?;
    opts.reasoning_n = a.parse_or("reasoning-n", 0usize)?;
    opts.shots = a.parse_or("shots", 5usize)?;
    opts.seed = a.parse_or("seed", 0u64)?;
    Ok(opts)
}

/// Resolve `--trace-out` (CLI wins) or `INVAREXPLORE_TRACE=<path>` and, if
/// tracing was requested, switch the recorder on before any spans fire.
fn trace_setup(a: &Args) -> Option<std::path::PathBuf> {
    let path = a
        .get("trace-out")
        .map(std::path::PathBuf::from)
        .or_else(crate::obs::trace_out_path)?;
    crate::obs::set_enabled(true);
    Some(path)
}

/// Dump the recorder to `path` as Chrome trace JSON and report the count.
fn trace_finish(path: &std::path::Path) -> crate::Result<()> {
    let n = crate::obs::chrome::dump(path)?;
    println!("trace: {n} events -> {}", path.display());
    Ok(())
}

pub fn main_with_args(argv: Vec<String>) -> crate::Result<i32> {
    crate::util::logging::init();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(2);
    };
    let spec = common_spec();
    let a = parse_args(&spec, &argv[1..])?;
    if a.flag("help") {
        print!("{USAGE}\n{}", usage(&spec));
        return Ok(0);
    }
    match cmd.as_str() {
        "info" => cmd_info(),
        "eval-ppl" => cmd_eval_ppl(&a),
        "eval-reasoning" => cmd_eval_reasoning(&a),
        "quantize" => cmd_quantize(&a),
        "search" => cmd_search(&a),
        "apply" => cmd_apply(&a),
        "serve" => cmd_serve(&a),
        "table1" => cmd_table(&a, 1),
        "table2" => cmd_table(&a, 2),
        "table3" => cmd_table(&a, 3),
        "table4" => cmd_table(&a, 4),
        "table5" => cmd_table(&a, 5),
        "figure1" => cmd_figure1(&a),
        _ => {
            eprintln!("unknown command {cmd:?}\n");
            print!("{USAGE}");
            Ok(2)
        }
    }
}

fn cmd_info() -> crate::Result<i32> {
    let session = Session::load_default()?;
    let m = &session.manifest;
    println!("artifacts root : {}", m.root.display());
    println!("batch geometry : B={} T={}", m.batch, m.seq);
    println!("quant schemes  : bits {:?} × groups {:?}", m.quant_bits, m.quant_groups);
    if !m.quant_allocations.is_empty() {
        let labels: Vec<String> = m.quant_allocations.iter().map(|a| a.label()).collect();
        println!("allocations    : {labels:?}");
    }
    println!("vocab          : {}", m.data.vocab);
    for (name, info) in &m.models {
        let c = &info.config;
        println!(
            "model {name:10} d={} L={} heads={} ffn={} params={:.2}M programs={}",
            c.d_model,
            c.n_layers,
            c.n_heads,
            c.d_ffn,
            c.num_params() as f64 / 1e6,
            info.programs.len()
        );
    }
    println!("corpora        : {:?}", m.data.corpora.iter().map(|(n, _)| n).collect::<Vec<_>>());
    println!("tasks          : {:?}", m.data.task_names());
    Ok(0)
}

fn cmd_eval_ppl(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let corpus = a.get_or("corpus", "wiki");
    let fp = pipeline::eval_fp(&session, &opts.model, &opts)?;
    println!("FP32 {}: wiki {:.3}  c4 {:.3}", opts.model, fp.ppl_wiki, fp.ppl_c4);
    let mut o = opts.clone();
    o.steps = 0;
    let r = pipeline::run_pipeline(&session, &o)?;
    println!(
        "{} {} ({}): wiki {:.3}  c4 {:.3}",
        o.method.name(),
        o.model,
        o.scheme,
        r.base.ppl_wiki,
        r.base.ppl_c4
    );
    let _ = corpus;
    Ok(0)
}

fn cmd_eval_reasoning(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let mut opts = opts_from_args(a)?;
    if opts.reasoning_n == 0 {
        opts.reasoning_n = 50;
    }
    opts.steps = 0;
    let r = pipeline::run_pipeline(&session, &opts)?;
    if let Some((results, avg)) = &r.base.reasoning {
        for t in results {
            println!("{:10} acc {:6.2} (n={})", t.task, t.accuracy, t.n);
        }
        println!("{:10} avg {avg:6.2}", "ALL");
    }
    Ok(0)
}

fn cmd_quantize(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = {
        let mut o = opts_from_args(a)?;
        o.steps = 0;
        o
    };
    let w = session.weights(&opts.model)?;
    let pile = session.corpus("pile")?;
    let calib = crate::calib::CalibSet::from_corpus(&pile, opts.calib_seqs, session.manifest.seq);
    let alloc = opts.allocation();
    let prepared = crate::baselines::prepare_mixed(opts.method, &alloc, &w, &calib, None)?;
    let (packed, bytes) = prepared.pack_model(&prepared.fp);
    let total_params: usize = packed.iter().map(|(_, t)| t.rows * t.cols).sum();
    let fp16_bytes = total_params * 2;
    println!(
        "{} {} {}: {} quantized tensors, packed {:.2} MiB vs FP16 {:.2} MiB ({:.1}% saving), {:.3} bits/param",
        opts.method.name(),
        opts.model,
        alloc.label(),
        packed.len(),
        bytes as f64 / (1 << 20) as f64,
        fp16_bytes as f64 / (1 << 20) as f64,
        100.0 * (1.0 - bytes as f64 / fp16_bytes as f64),
        bytes as f64 * 8.0 / total_params as f64,
    );
    let r = pipeline::run_pipeline(&session, &opts)?;
    println!("wiki ppl {:.3}  c4 ppl {:.3}", r.base.ppl_wiki, r.base.ppl_c4);
    if let Some(out) = a.get("out") {
        save_weights(&prepared.quantize_model(&prepared.fp, None), std::path::Path::new(out))?;
        println!("dequantized weights written to {out}");
    }
    Ok(0)
}

fn cmd_search(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let trace = trace_setup(a);
    if let Some(resume) = a.get("resume") {
        let rc = cmd_search_resume(&session, &opts, a, resume)?;
        if let Some(path) = &trace {
            search_trace_report(path)?;
        }
        return Ok(rc);
    }
    let r = pipeline::run_pipeline(&session, &opts)?;
    println!(
        "baseline {}: wiki {:.3}  c4 {:.3}",
        opts.method.name(),
        r.base.ppl_wiki,
        r.base.ppl_c4
    );
    if let Some(s) = &r.searched {
        println!(
            "+InvarExplore({}, {} steps): wiki {:.3}  c4 {:.3}",
            opts.kinds.label(),
            opts.steps,
            s.ppl_wiki,
            s.ppl_c4
        );
    }
    if let Some(state) = &r.state {
        println!(
            "accepted {}/{} proposals ({:.1}%), final loss {:.4}",
            state.accepts,
            state.step,
            100.0 * state.accept_rate(),
            state.best.total(state.alpha)
        );
        if let Some(alloc) = &state.alloc {
            println!(
                "searched allocation ({} bit swaps accepted, {:.3} bits/param <= budget {:.3}): {}",
                state.alloc_accepts,
                alloc.bits_per_param(),
                alloc.budget,
                alloc.to_allocation(opts.scheme).label()
            );
        }
        if let Some(out) = a.get("out") {
            state.save(std::path::Path::new(out))?;
            println!("search state saved to {out}");
        }
        if let Some(csv) = a.get("csv") {
            state.telemetry_csv(std::path::Path::new(csv))?;
            println!("telemetry written to {csv}");
        }
    }
    if let Some(path) = &trace {
        search_trace_report(path)?;
    }
    Ok(0)
}

/// Chrome trace + Prometheus text for a search run (move-family acceptance
/// and per-tier kernel throughput; no serve metrics in this path).
fn search_trace_report(path: &std::path::Path) -> crate::Result<()> {
    trace_finish(path)?;
    print!("{}", crate::obs::prometheus::render_search(&crate::obs::search::snapshot()));
    print!("{}", crate::obs::prometheus::render_kernel(&crate::obs::kernel::snapshot()));
    Ok(())
}

/// `search --resume state.json`: restore a checkpoint, continue for
/// `--steps` more proposals, re-evaluate and save back.
fn cmd_search_resume(
    session: &Session,
    opts: &PipelineOpts,
    a: &Args,
    resume: &str,
) -> crate::Result<i32> {
    let saved = crate::search::SearchState::load(std::path::Path::new(resume), opts.seed)?;
    let mut run = pipeline::SearchRun::build(session, opts)?;
    run.restore(saved)?;
    let before = run.state.best.total(run.state.alpha);
    run.steps(opts.steps)?;
    let snap = run.snapshot(session, opts)?;
    println!(
        "resumed +{} steps: loss {:.4} -> {:.4}, wiki ppl {:.3}, c4 ppl {:.3}",
        opts.steps,
        before,
        run.state.best.total(run.state.alpha),
        snap.ppl_wiki,
        snap.ppl_c4
    );
    let out = a.get("out").unwrap_or(resume);
    run.state.save(std::path::Path::new(out))?;
    println!("state saved to {out}");
    if let Some(csv) = a.get("csv") {
        run.state.telemetry_csv(std::path::Path::new(csv))?;
    }
    Ok(0)
}

fn cmd_apply(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let state_path = a.req("csv").ok(); // not used; keep CLI simple
    let _ = state_path;
    let state_file = a
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: invarexplore apply <state.json> --out w.iwt"))?;
    let out = a.req("out")?;
    let state = crate::search::SearchState::load(std::path::Path::new(state_file), opts.seed)?;

    let w = session.weights(&opts.model)?;
    let pile = session.corpus("pile")?;
    let calib = crate::calib::CalibSet::from_corpus(&pile, opts.calib_seqs, session.manifest.seq);
    let prepared =
        crate::baselines::prepare_mixed(opts.method, &opts.allocation(), &w, &calib, None)?;
    // apply transforms to FP weights (batched across the thread pool),
    // then quantize under the method
    let mut transformed = prepared.fp.clone();
    let reqs: Vec<(usize, &crate::transform::LayerTransform)> =
        state.transforms.iter().enumerate().collect();
    for (&(l, _), (wu, bu, wd)) in
        reqs.iter().zip(crate::transform::apply_batch(&prepared.fp, &reqs))
    {
        transformed.set(&format!("l{l}.up.w"), wu);
        transformed.set(&format!("l{l}.up.b"), bu);
        transformed.set(&format!("l{l}.down.w"), wd);
    }
    let q = prepared.quantize_model(&transformed, Some(&state.transforms));
    save_weights(&q, std::path::Path::new(out))?;
    println!("applied {} layer transforms; quantized weights written to {out}", state.transforms.len());
    Ok(0)
}

/// Cheapest manifest allocation preset strictly under the target's budget
/// (validated against the model), else one bit below the target's default
/// scheme — the "nearly free in memory" draft self-speculative decoding
/// wants.  `None` when no strictly-cheaper viable allocation exists (the
/// caller then serves without speculation).
fn default_draft_allocation(
    manifest: &crate::io::manifest::Manifest,
    target: &crate::quant::BitAllocation,
    cfg: &crate::model::OptConfig,
) -> Option<crate::quant::BitAllocation> {
    let budget = target.bits_per_param(cfg);
    let preset = manifest
        .quant_allocations
        .iter()
        .filter(|al| al.validate(cfg).is_ok() && al.bits_per_param(cfg) < budget)
        .min_by(|x, y| x.bits_per_param(cfg).partial_cmp(&y.bits_per_param(cfg)).unwrap());
    if let Some(p) = preset {
        return Some(p.clone());
    }
    let fallback = crate::quant::BitAllocation::uniform(QuantScheme::new(
        (target.default.bits.saturating_sub(1)).max(1),
        target.default.group,
    ));
    (fallback.validate(cfg).is_ok() && fallback.bits_per_param(cfg) < budget).then_some(fallback)
}

/// `invarexplore serve`: quantize + pack the model under `--alloc`, then
/// drive the serving stack on synthetic shared-prefix wiki traffic — the
/// prefix-affinity [`crate::serve::Router`] over `--replicas` schedulers
/// (with `--shed-watermark` load shedding), each computing on the packed
/// weights directly or on `--shards` tensor-parallel row shards
/// ([`crate::serve::ShardedModel`], bit-identical at any shard count) —
/// with self-speculative decoding (`--spec k` / `SERVE_SPEC`) drafting on
/// an aggressive low-bit re-quantization of the same base weights
/// (`--draft-alloc`, defaulting to the cheapest manifest preset).
fn cmd_serve(a: &Args) -> crate::Result<i32> {
    use crate::serve::{AdmissionPolicy, Request, Router, RouterOpts, ServeOpts, ShardedModel};
    use crate::util::sampling::Sampler;

    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let trace = trace_setup(a);
    let alloc = opts.allocation();
    let w = session.weights(&opts.model)?;
    let pile = session.corpus("pile")?;
    let calib = crate::calib::CalibSet::from_corpus(&pile, opts.calib_seqs, session.manifest.seq);
    let prepared = crate::baselines::prepare_mixed(opts.method, &alloc, &w, &calib, None)?;
    let quantized = prepared.quantize_model(&prepared.fp, None);
    let pm = prepared.packed_model(&quantized);
    println!(
        "== serving {} at {} ({:.2} MiB packed, {}) ==",
        opts.model,
        alloc.label(),
        pm.packed_bytes() as f64 / (1 << 20) as f64,
        pm.bits_summary()
    );

    let spec = match a.get("spec") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --spec {v:?} (want a draft length)"))?,
        None => crate::util::cli::env_override("SERVE_SPEC", 0usize),
    };
    let policy = match a
        .get("policy")
        .map(str::to_string)
        .or_else(|| std::env::var("SERVE_POLICY").ok())
    {
        Some(v) => AdmissionPolicy::parse(&v)?,
        None => AdmissionPolicy::Fcfs,
    };
    let sampler = match a
        .get("sampler")
        .map(str::to_string)
        .or_else(|| std::env::var("SERVE_SAMPLER").ok())
    {
        Some(v) => Sampler::parse(&v)?,
        None => Sampler::Greedy,
    };
    let kv_dtype = match a
        .get("kv-dtype")
        .map(str::to_string)
        .or_else(|| std::env::var("SERVE_KV_DTYPE").ok())
    {
        Some(v) => crate::model::native::KvDtype::parse(&v)?,
        None => crate::model::native::KvDtype::F32,
    };
    let n_requests = a.parse_or("requests", 8usize)?.max(1);
    let max_new = a.parse_or("max-new", 24usize)?;
    let replicas = match a.get("replicas") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --replicas {v:?} (want a count)"))?,
        None => crate::util::cli::env_override("SERVE_REPLICAS", 1usize),
    }
    .max(1);
    let shards = match a.get("shards") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --shards {v:?} (want a count)"))?,
        None => crate::util::cli::env_override("SERVE_SHARDS", 1usize),
    }
    .max(1);
    let shed_watermark = match a.get("shed-watermark") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("bad --shed-watermark {v:?} (want a queue depth)"))?,
        None => crate::util::cli::env_override("SERVE_SHED_WATERMARK", 0usize),
    };
    let fault_plan = match a.get("fault-plan") {
        Some(v) => Some(crate::serve::FaultPlan::parse(v)?),
        None => crate::serve::FaultPlan::from_env()?,
    }
    .filter(|p| !p.is_empty());
    let round_budget_ms = match a.get("round-budget-ms") {
        Some(v) => v
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("bad --round-budget-ms {v:?} (want milliseconds)"))?,
        None => crate::util::cli::env_override("SERVE_ROUND_BUDGET_MS", 0u64),
    };

    let draft_alloc = match a
        .get("draft-alloc")
        .map(str::to_string)
        .or_else(|| std::env::var("SERVE_DRAFT_ALLOC").ok())
    {
        Some(s) => Some(crate::quant::BitAllocation::parse(&s)?),
        None => default_draft_allocation(&session.manifest, &alloc, pm.config()),
    };
    let draft = match (spec > 0, draft_alloc) {
        (true, Some(da)) => {
            let d = pm.draft(&da)?;
            println!(
                "draft model ({} tokens/round): {} — {:.2} MiB next to the target's {:.2} MiB",
                spec,
                da.label(),
                d.packed_bytes() as f64 / (1 << 20) as f64,
                pm.packed_bytes() as f64 / (1 << 20) as f64
            );
            Some(d)
        }
        (true, None) => {
            println!("serve: no allocation cheaper than the target; speculation disabled");
            None
        }
        _ => None,
    };

    let serve_opts = ServeOpts {
        max_batch: a.parse_or("max-batch", 4usize)?.max(1),
        seed: opts.seed,
        policy,
        prefix_cache: true,
        spec,
        kv_dtype,
        round_budget_ms: (round_budget_ms > 0).then_some(round_budget_ms),
        ..Default::default()
    };
    if kv_dtype != crate::model::native::KvDtype::F32 {
        println!("kv cache stored as {} (documented-tolerance mode)", kv_dtype.label());
    }
    let sharded = (shards > 1).then(|| ShardedModel::new(&pm, shards));
    let params: &dyn crate::model::native::DecoderParams = match &sharded {
        Some(sm) => {
            println!(
                "tensor-parallel: {} row shards, {:?} packed bytes per shard (bit-identical)",
                sm.n_shards(),
                sm.packed_bytes_per_shard()
            );
            sm
        }
        None => &pm,
    };
    let router_opts = RouterOpts { replicas, shed_watermark, ..Default::default() };
    let mut router = Router::new(params, router_opts, serve_opts);
    if let Some(d) = &draft {
        router = router.with_draft(d);
    }
    if let Some(plan) = fault_plan {
        println!("fault injection armed: {plan:?}");
        router = router.with_fault_plan(plan);
    }

    // synthetic shared-prefix wiki traffic (two prompt families, so the
    // prefix cache and the speculative path are both exercised)
    let max_seq = pm.config().max_seq;
    let prompt_len = usize::min(32, max_seq / 2);
    let shared_len = prompt_len / 2;
    let wiki = session.corpus("wiki")?;
    anyhow::ensure!(
        wiki.tokens.len() > prompt_len,
        "wiki corpus too small for a {prompt_len}-token prompt"
    );
    let mut rng = crate::util::rng::Pcg64::new(opts.seed ^ 0x5e7e);
    let starts: Vec<usize> =
        (0..2).map(|_| rng.below(wiki.tokens.len() - prompt_len)).collect();
    for i in 0..n_requests {
        let base = starts[i % 2];
        let tail_at = rng.below(wiki.tokens.len() - prompt_len);
        let prompt: Vec<i32> = wiki.tokens[base..base + shared_len]
            .iter()
            .chain(&wiki.tokens[tail_at..tail_at + (prompt_len - shared_len)])
            .map(|&t| t as i32)
            .collect();
        router.submit(Request::new(i, prompt, max_new, sampler));
    }

    let (completions, rstats) = if a.flag("drain") {
        let d = router.shutdown();
        println!("drain: {}", d.summary());
        (d.completions, d.stats)
    } else {
        router.run()
    };
    if rstats.replica_deaths > 0 {
        println!(
            "supervision: {} replica death(s), {} redispatched, {} failed, {} live replica(s)",
            rstats.replica_deaths,
            rstats.redispatched,
            rstats.failed_requests,
            router.live_replicas()
        );
    }
    if replicas > 1 || shed_watermark > 0 {
        println!(
            "router: {} submitted — {} affinity, {} balanced, {} spilled, {} shed (rate {:.2})",
            rstats.submitted,
            rstats.affinity_routed,
            rstats.balanced,
            rstats.spilled,
            rstats.shed,
            rstats.shed_rate()
        );
    }
    for (i, s) in rstats.per_replica.iter().enumerate() {
        if rstats.per_replica.len() > 1 {
            println!("replica {i}: {}", s.summary());
        } else {
            println!("{}", s.summary());
        }
    }
    for c in completions.iter().take(2) {
        let head = &c.generated[..c.generated.len().min(8)];
        println!("sample {} ({}): -> {head:?}", c.id, c.finish.label());
    }
    let metrics = router.aggregate_metrics();
    println!("metrics: {}", metrics.to_json().to_string());
    if let Some(path) = &trace {
        trace_finish(path)?;
        // render() appends the kernel/search/router counter sections
        print!("{}", crate::obs::prometheus::render(&metrics));
    }
    Ok(0)
}

fn save_weights(w: &crate::model::Weights, path: &std::path::Path) -> crate::Result<()> {
    let entries: Vec<(String, &crate::tensor::Tensor, Vec<usize>)> = w
        .in_order()
        .into_iter()
        .map(|(n, t)| {
            let shape = if crate::runtime::engine::is_vector_param(n) {
                vec![t.cols]
            } else {
                vec![t.rows, t.cols]
            };
            (n.to_string(), t, shape)
        })
        .collect();
    let meta = w
        .config
        .param_names()
        .is_empty()
        .then(std::collections::BTreeMap::new)
        .unwrap_or_default();
    crate::io::iwt::write(path, &entries, &meta)
}

fn cmd_table(a: &Args, which: usize) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let steps = opts.steps;
    let out = match which {
        1 => {
            let t1 = tables::Table1Opts {
                models: session.manifest.model_names().iter().map(|s| s.to_string()).collect(),
                methods: vec![Method::Rtn, Method::Gptq, Method::Awq, Method::OmniQuant],
                scheme: opts.scheme,
                steps,
                reasoning_n: opts.reasoning_n,
                seed: opts.seed,
            };
            tables::table1(&session, &t1)?
        }
        2 => tables::table2(&session, &opts.model, opts.scheme, steps, opts.reasoning_n, opts.seed)?,
        3 => tables::table3(&session, &opts.model, steps, opts.reasoning_n, opts.seed)?,
        4 => tables::table4(&session, &opts.model, opts.scheme, steps, opts.reasoning_n, opts.seed)?,
        5 => tables::table5(
            &session,
            &[opts.model.clone()],
            opts.scheme,
            steps,
            opts.reasoning_n.max(30),
            opts.seed,
        )?,
        _ => unreachable!(),
    };
    println!("{out}");
    Ok(0)
}

fn cmd_figure1(a: &Args) -> crate::Result<i32> {
    let session = Session::load_default()?;
    let opts = opts_from_args(a)?;
    let f1 = tables::Figure1Opts {
        model: opts.model.clone(),
        scheme: opts.scheme,
        calib_seqs: vec![1, 8, 32],
        total_steps: opts.steps,
        segments: 8,
        seed: opts.seed,
    };
    let out = tables::figure1(&session, &f1)?;
    println!("{out}");
    Ok(0)
}

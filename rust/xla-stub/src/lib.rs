//! Stub of the `xla` (PJRT) bindings used by `invarexplore::runtime`.
//!
//! The real bindings wrap the `xla_extension` C++ closure, which is not
//! vendorable here.  This stub reproduces exactly the API surface the crate
//! consumes — so the whole runtime layer type-checks and the binary builds —
//! while every operation that would need a device returns [`Error`] with a
//! clear message.  Artifact-gated integration tests and benches detect the
//! missing runtime (via `Session::load_default` / `PjRtClient::cpu`) and
//! skip.
//!
//! API surface (keep in sync with `runtime/{client,engine,evaluator}.rs`):
//!
//! * `PjRtClient::{cpu, platform_name, device_count, compile,
//!   buffer_from_host_buffer}`
//! * `PjRtLoadedExecutable::execute_b`
//! * `PjRtBuffer::to_literal_sync`
//! * `Literal::{shape, array_shape, to_vec, to_tuple}`
//! * `HloModuleProto::from_text_file`, `XlaComputation::from_proto`
//! * `Shape::Tuple`, `ArrayShape::dims`

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real bindings' error enum closely enough for
/// `?`-conversion into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "XLA backend unavailable: built against the bundled `xla` stub crate \
         (rust/xla-stub). Point the `xla` dependency in rust/Cargo.toml at \
         real PJRT bindings to enable device execution."
            .to_string(),
    )
}

/// Array shape: element dims (row-major, i64 as in the real bindings).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// XLA shape: an array or a tuple of shapes.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Host-side literal (never constructible through the stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn shape(&self) -> Result<Shape> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Device buffer (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A device handle (only ever passed as `None` by this crate).
#[derive(Debug)]
pub struct PjRtDevice {
    _private: (),
}

/// Compiled + loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on borrowed buffers; outputs per device (the crate uses
    /// single-device execution and takes `out[0]`).
    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client.  `cpu()` fails fast so callers can gate on runtime
/// availability with one call.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping a module proto.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn error_converts_to_anyhow_like_boxed_error() {
        fn takes_std_error<E: std::error::Error + Send + Sync + 'static>(_e: E) {}
        takes_std_error(unavailable());
    }
}

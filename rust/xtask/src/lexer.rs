//! Line-oriented lexical pass over Rust source.
//!
//! Splits every line into a *code* channel and a *comment* channel:
//! string/char literal contents are blanked (delimiters kept so column
//! structure survives), comments are moved wholesale to the comment
//! channel. Downstream lint rules then pattern-match on the code channel
//! without false positives from literals, and look up annotations
//! (`SAFETY:`, `CLAMPED:`, ...) on the comment channel.
//!
//! This is deliberately *lexical*, not syntactic: it has to run on stable
//! with zero dependencies, and every invariant we check is expressible at
//! line granularity. Handled Rust lexical edge cases: raw strings
//! (`r"..."`, `r#"..."#`, any hash depth), byte strings, nested block
//! comments, escaped char literals, and char-literal-vs-lifetime
//! disambiguation (`'a'` vs `'a`).

/// One source line after lexing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Line {
    /// Code with literal contents blanked and comments removed.
    pub code: String,
    /// Concatenated comment text from this line (line + block comments).
    pub comment: String,
}

#[derive(PartialEq, Eq, Clone, Copy)]
enum Mode {
    Normal,
    LineComment,
    BlockComment,
    Str,
    RawStr,
}

/// Lex `src` into per-line code/comment channels.
pub fn split_lines(src: &str) -> Vec<Line> {
    let b = src.as_bytes();
    let n = b.len();
    let mut lines = Vec::new();
    let mut code: Vec<u8> = Vec::new();
    let mut comment: Vec<u8> = Vec::new();
    let mut mode = Mode::Normal;
    let mut block_depth = 0u32;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        let nxt = if i + 1 < n { b[i + 1] } else { 0 };
        if c == b'\n' {
            lines.push(flush(&mut code, &mut comment));
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Normal;
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Normal => {
                if c == b'/' && nxt == b'/' {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == b'/' && nxt == b'*' {
                    mode = Mode::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == b'"' {
                    code.push(b'"');
                    mode = Mode::Str;
                    i += 1;
                } else if c == b'r' && (nxt == b'"' || nxt == b'#') {
                    // Raw string candidate: r"..." or r#"..."# (any hash depth).
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && b[j] == b'#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        code.push(b'r');
                        code.resize(code.len() + hashes, b'#');
                        code.push(b'"');
                        mode = Mode::RawStr;
                        raw_hashes = hashes;
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == b'b' && nxt == b'"' {
                    code.extend_from_slice(b"b\"");
                    mode = Mode::Str;
                    i += 2;
                } else if c == b'\'' {
                    // Char literal vs lifetime.
                    if nxt == b'\\' {
                        // Escaped char literal: consume through closing quote.
                        code.extend_from_slice(b"' '");
                        let mut j = i + 2;
                        if j < n {
                            j += 1; // the escaped character itself
                        }
                        while j < n && b[j] != b'\'' {
                            j += 1;
                        }
                        i = j + 1;
                    } else if i + 2 < n && b[i + 2] == b'\'' {
                        code.extend_from_slice(b"' '");
                        i += 3;
                    } else {
                        // Lifetime: keep the tick, continue normally.
                        code.push(c);
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                comment.push(c);
                i += 1;
            }
            Mode::BlockComment => {
                if c == b'/' && nxt == b'*' {
                    block_depth += 1;
                    i += 2;
                } else if c == b'*' && nxt == b'/' {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        mode = Mode::Normal;
                    }
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == b'\\' {
                    code.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    code.push(b'"');
                    mode = Mode::Normal;
                    i += 1;
                } else {
                    code.push(b' ');
                    i += 1;
                }
            }
            Mode::RawStr => {
                let h = raw_hashes;
                let closes =
                    c == b'"' && i + 1 + h <= n && b[i + 1..i + 1 + h].iter().all(|&x| x == b'#');
                if closes {
                    code.push(b'"');
                    code.resize(code.len() + h, b'#');
                    mode = Mode::Normal;
                    i += 1 + h;
                } else {
                    code.push(b' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(flush(&mut code, &mut comment));
    }
    lines
}

fn flush(code: &mut Vec<u8>, comment: &mut Vec<u8>) -> Line {
    let line = Line {
        code: String::from_utf8_lossy(code).into_owned(),
        comment: String::from_utf8_lossy(comment).into_owned(),
    };
    code.clear();
    comment.clear();
    line
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// True if `tok` appears in `code` with non-identifier characters (or the
/// line boundary) on both sides.
pub fn has_token(code: &str, tok: &str) -> bool {
    let cb = code.as_bytes();
    let tb = tok.as_bytes();
    let mut start = 0usize;
    while start + tb.len() <= cb.len() {
        match code[start..].find(tok) {
            None => return false,
            Some(off) => {
                let k = start + off;
                let before_ok = k == 0 || !is_ident(cb[k - 1]);
                let after_ok = k + tb.len() >= cb.len() || !is_ident(cb[k + tb.len()]);
                if before_ok && after_ok {
                    return true;
                }
                start = k + 1;
            }
        }
    }
    false
}

/// Mark lines that belong to `#[cfg(test)]` items (the attribute line, the
/// item header, and everything inside its braces), by brace-depth tracking
/// on the code channel. String-blanking upstream means `{}` inside format
/// strings cannot corrupt the depth count.
pub fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut pending_attr = false;
    let mut pending_depth: i64 = 0;
    let mut region_stack: Vec<i64> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let stripped = line.code.trim();
        if stripped.starts_with("#[cfg") && has_token(&line.code, "test") {
            pending_attr = true;
            pending_depth = depth;
            in_test[idx] = true;
        }
        if !region_stack.is_empty() || pending_attr {
            in_test[idx] = true;
        }
        for ch in line.code.bytes() {
            match ch {
                b'{' => {
                    if pending_attr {
                        region_stack.push(depth);
                        pending_attr = false;
                    }
                    depth += 1;
                }
                b'}' => {
                    depth -= 1;
                    if region_stack.last() == Some(&depth) {
                        region_stack.pop();
                    }
                }
                b';' => {
                    // `#[cfg(test)] use ...;` — attribute consumed by a
                    // braceless item at the same depth.
                    if pending_attr && depth == pending_depth {
                        pending_attr = false;
                    }
                }
                _ => {}
            }
        }
    }
    in_test
}

/// True if line `idx` carries one of `tags` (with non-empty justification
/// text after a `:`-terminated tag) in its own comment or in the contiguous
/// comment/attribute block immediately above it.
pub fn annotated(lines: &[Line], idx: usize, tags: &[&str]) -> bool {
    let ok = |comment: &str| -> bool {
        for t in tags {
            if let Some(k) = comment.find(t) {
                if t.ends_with(':') {
                    if !comment[k + t.len()..].trim().is_empty() {
                        return true;
                    }
                } else {
                    return true;
                }
            }
        }
        false
    };
    if ok(&lines[idx].comment) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let s = lines[j].code.trim();
        if !s.is_empty() && !s.starts_with("#[") {
            return false;
        }
        if ok(&lines[j].comment) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        split_lines(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments() {
        let l = split_lines("let x = 1; // SAFETY: fine\n");
        assert_eq!(l[0].code, "let x = 1; ");
        assert!(l[0].comment.contains("SAFETY: fine"));
    }

    #[test]
    fn blanks_string_contents() {
        let c = codes("let s = \"unsafe { as u8 }\";\n");
        assert!(!c[0].contains("unsafe"));
        assert!(!c[0].contains("as u8"));
        assert!(c[0].contains('"'));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = codes("let s = r#\"has \"quotes\" and unsafe\"#; let y = 2;\n");
        assert!(!c[0].contains("unsafe"));
        assert!(c[0].contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments() {
        let c = codes("a /* outer /* inner */ still comment */ b\n");
        assert_eq!(c[0].replace(' ', ""), "ab");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = codes("let c = '{'; fn f<'a>(x: &'a str) {}\n");
        // The brace inside the char literal must be blanked...
        assert!(!c[0].contains('{') || c[0].matches('{').count() == 1);
        // ...while the lifetime tick survives without eating code.
        assert!(c[0].contains("&'a str"));
    }

    #[test]
    fn escaped_char_literal() {
        let c = codes("let q = '\\''; let z = 1;\n");
        assert!(c[0].contains("let z = 1;"));
    }

    #[test]
    fn format_string_braces_do_not_break_depth() {
        let src = "#[cfg(test)]\nmod t {\n    fn f() { let _ = \"{{{}}\"; }\n}\nfn g() {}\n";
        let lines = split_lines(src);
        let regions = test_regions(&lines);
        assert_eq!(regions, vec![true, true, true, true, false]);
    }

    #[test]
    fn cfg_test_use_item_does_not_capture_rest_of_file() {
        let src = "#[cfg(test)]\nuse crate::x;\nfn live() {}\n";
        let lines = split_lines(src);
        let regions = test_regions(&lines);
        assert!(regions[0] && regions[1]);
        assert!(!regions[2]);
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("unsafer {", "unsafe"));
        assert!(!has_token("an_unsafe {", "unsafe"));
        assert!(has_token("use std::collections::HashMap;", "HashMap"));
    }

    #[test]
    fn annotation_same_line_and_above() {
        let src = "// SAFETY: ptr valid\nunsafe { x() }\nunsafe { y() } // SAFETY: y ok\n";
        let lines = split_lines(src);
        assert!(annotated(&lines, 1, &["SAFETY:"]));
        assert!(annotated(&lines, 2, &["SAFETY:"]));
        assert!(!annotated(&lines, 0, &["CLAMPED:"]));
    }

    #[test]
    fn empty_justification_rejected() {
        let lines = split_lines("// SAFETY:\nunsafe { x() }\n");
        assert!(!annotated(&lines, 1, &["SAFETY:"]));
    }

    #[test]
    fn annotation_blocked_by_code_line() {
        let lines = split_lines("// SAFETY: for the other block\nlet a = 1;\nunsafe { x() }\n");
        assert!(!annotated(&lines, 2, &["SAFETY:"]));
    }

    #[test]
    fn doc_safety_section_accepted() {
        let src = "/// # Safety\n/// caller is checked\n#[inline]\nunsafe fn f() {}\n";
        let lines = split_lines(src);
        assert!(annotated(&lines, 3, &["SAFETY:", "# Safety"]));
    }
}

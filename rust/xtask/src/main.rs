use std::path::PathBuf;
use std::process::ExitCode;

use xtask::audit::{audit_tree, check_baseline, render_json};
use xtask::lint::lint_tree;
use xtask::{default_roots, workspace_root};

const USAGE: &str = "\
usage: cargo xtask <command>

commands:
  lint [--root DIR]...        run the invariant lints (default roots:
                              src, benches, xla-stub/src, xtask/src)
  envdoc [--root DIR]...      fail on env-var reads not documented in the
                              README env-knob table (default roots: src,
                              benches)
  mdlint                      markdown hygiene: dead relative links and
                              untagged code fences in README.md,
                              CONTRIBUTING.md and docs/*.md
  audit                       print the unsafe/panic/cast audit as JSON
  audit --write               regenerate rust/AUDIT.json static counters
  audit --check-baseline      fail if the surface regressed vs rust/AUDIT.json
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => cmd_lint(&args[1..]),
        Some("envdoc") => cmd_envdoc(&args[1..]),
        Some("mdlint") => cmd_mdlint(&args[1..]),
        Some("audit") => cmd_audit(&args[1..]),
        _ => {
            eprint!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn cmd_lint(args: &[String]) -> ExitCode {
    let base = workspace_root();
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(dir) => roots.push(PathBuf::from(dir)),
                None => {
                    eprintln!("--root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown lint argument: {other}");
                return ExitCode::from(2);
            }
        }
    }
    if roots.is_empty() {
        roots = default_roots();
    }
    let violations = match lint_tree(&base, &roots) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("lint: io error: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: clean");
        ExitCode::SUCCESS
    } else {
        println!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn cmd_envdoc(args: &[String]) -> ExitCode {
    let base = workspace_root();
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(dir) => roots.push(PathBuf::from(dir)),
                None => {
                    eprintln!("--root needs a directory argument");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("unknown envdoc argument: {other}");
                return ExitCode::from(2);
            }
        }
    }
    if roots.is_empty() {
        roots = xtask::envdoc::default_roots();
    }
    let readme_path = xtask::envdoc::readme_path();
    let readme = match std::fs::read_to_string(&readme_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("envdoc: cannot read {}: {e}", readme_path.display());
            return ExitCode::from(2);
        }
    };
    let documented = xtask::envdoc::documented_vars(&readme);
    let violations = match xtask::envdoc::check_tree(&base, &roots, &documented) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("envdoc: io error: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("xtask envdoc: every env knob documented ({} known)", documented.len());
        ExitCode::SUCCESS
    } else {
        println!(
            "xtask envdoc: {} undocumented env read(s) — add the variable to the \
             README env-knob table or justify the site with // ENV-DOC: <why>",
            violations.len()
        );
        ExitCode::FAILURE
    }
}

fn cmd_mdlint(args: &[String]) -> ExitCode {
    if !args.is_empty() {
        eprintln!("mdlint takes no arguments");
        return ExitCode::from(2);
    }
    let docs = xtask::mdlint::default_docs();
    let violations = match xtask::mdlint::check_docs(&docs) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("mdlint: io error: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("xtask mdlint: {} document(s) clean", docs.len());
        ExitCode::SUCCESS
    } else {
        println!("xtask mdlint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

/// Minimal temp + fsync + rename write so a killed `audit --write` can't
/// leave a torn AUDIT.json (mirrors the main crate's `util::atomic_write`,
/// which xtask deliberately doesn't depend on).
fn atomic_write(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let mut tmp = path.to_path_buf();
    let name = match path.file_name().and_then(|n| n.to_str()) {
        Some(n) => n,
        None => "audit",
    };
    tmp.set_file_name(format!(".{name}.tmp.{}", std::process::id()));
    let mut f = std::fs::File::create(&tmp)?;
    if let Err(e) = f.write_all(bytes).and_then(|()| f.sync_all()) {
        drop(f);
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    drop(f);
    std::fs::rename(&tmp, path)
}

fn cmd_audit(args: &[String]) -> ExitCode {
    let base = workspace_root();
    let roots = default_roots();
    let audit = match audit_tree(&base, &roots) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("audit: io error: {e}");
            return ExitCode::from(2);
        }
    };
    let json = render_json(&audit);
    let baseline_path = base.join("AUDIT.json");
    match args.first().map(String::as_str) {
        None => {
            print!("{json}");
            ExitCode::SUCCESS
        }
        Some("--write") => {
            if let Err(e) = atomic_write(&baseline_path, json.as_bytes()) {
                eprintln!("audit: cannot write {}: {e}", baseline_path.display());
                return ExitCode::from(2);
            }
            println!("wrote {}", baseline_path.display());
            ExitCode::SUCCESS
        }
        Some("--check-baseline") => {
            let baseline = match std::fs::read_to_string(&baseline_path) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("audit: cannot read {}: {e}", baseline_path.display());
                    return ExitCode::from(2);
                }
            };
            let fails = check_baseline(&audit, &baseline);
            if fails.is_empty() {
                println!("xtask audit: surface within baseline");
                println!(
                    "  unsafe {}/{} annotated, serve panics {}/{} justified",
                    audit.unsafe_safety_annotated,
                    audit.unsafe_total,
                    audit.serve_panic_ok,
                    audit.serve_panic_sites
                );
                ExitCode::SUCCESS
            } else {
                for f in &fails {
                    eprintln!("xtask audit: {f}");
                }
                ExitCode::FAILURE
            }
        }
        Some(other) => {
            eprintln!("unknown audit argument: {other}");
            ExitCode::from(2)
        }
    }
}

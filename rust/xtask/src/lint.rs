//! Repo-specific invariant lints.
//!
//! Four rules, each tied to a historical or structural failure mode of
//! this codebase (see README "Correctness tooling"):
//!
//! 1. `undocumented-unsafe` — any `unsafe` keyword without a `SAFETY:`
//!    (or `# Safety` doc section) justification. Applies everywhere,
//!    tests included: unsound test helpers are still unsound.
//! 2. `unclamped-cast` — truncating integer casts (`as u8` / `as u16` /
//!    `as i8`) in `quant/` or `model/` without a same-line `clamp(` or a
//!    `CLAMPED:` justification. This is the PR-2 bug class: an unclamped
//!    `z as u8` zero-point silently corrupted PackedTensor for
//!    single-sign groups.
//! 3. `serve-panic-path` — `unwrap`/`expect`/`panic!`-family calls in
//!    `serve/` outside a `PANIC-OK: <why unreachable>` annotation.
//!    Malformed requests must end in `FinishReason::Rejected`, never
//!    abort a batch.
//! 4. `nondet-*` — nondeterminism hazards in bit-identity code:
//!    `std::collections::HashMap`/`HashSet` imports (iteration order) in
//!    `quant/`, `model/`, `serve/`; wall clocks (`Instant`/`SystemTime`)
//!    in `quant/`, `model/`, `obs/`; ambient RNG (`thread_rng`,
//!    `from_entropy`, `RandomState`, `getrandom`) anywhere in those.
//!    Each needs a `DETERMINISM:` note arguing why determinism is
//!    preserved. `obs/` is in the clock scope because it is the one
//!    module compute code calls from bit-identity paths: every clock
//!    read there must argue it can only affect telemetry, never values.
//!
//! Every escape hatch is a per-site annotation with mandatory
//! justification text — there is no file-level or blanket exemption.

use crate::lexer::{annotated, has_token, split_lines, test_regions, Line};
use std::path::{Path, PathBuf};

pub const SAFETY_TAGS: &[&str] = &["SAFETY:", "# Safety"];
pub const CLAMPED_TAGS: &[&str] = &["CLAMPED:"];
pub const PANIC_OK_TAGS: &[&str] = &["PANIC-OK:"];
pub const DETERMINISM_TAGS: &[&str] = &["DETERMINISM:"];

const PANIC_PATTERNS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];
const CAST_PATTERNS: &[&str] = &["as u8", "as u16", "as i8"];
const RNG_TOKENS: &[&str] = &["thread_rng", "from_entropy", "RandomState", "getrandom"];
const CLOCK_TOKENS: &[&str] = &["Instant", "SystemTime"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub snippet: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.snippet)
    }
}

/// Path scope of a file, derived from its directory components.
#[derive(Debug, Clone, Copy, Default)]
pub struct Scope {
    pub quant: bool,
    pub model: bool,
    pub serve: bool,
    pub obs: bool,
}

pub fn scope_of(rel: &str) -> Scope {
    let mut s = Scope::default();
    for comp in rel.split(['/', '\\']) {
        match comp {
            "quant" => s.quant = true,
            "model" => s.model = true,
            "serve" => s.serve = true,
            "obs" => s.obs = true,
            _ => {}
        }
    }
    s
}

fn snippet(code: &str) -> String {
    let t = code.trim();
    let mut s: String = t.chars().take(60).collect();
    if t.chars().count() > 60 {
        s.push_str("...");
    }
    s
}

/// True if `code` contains `pat` as a token-bounded phrase (the character
/// after the match must not extend an identifier, so `as u8` does not
/// match inside `as u8x16`).
pub fn has_cast(code: &str, pat: &str) -> bool {
    let cb = code.as_bytes();
    let mut start = 0usize;
    while let Some(off) = code[start..].find(pat) {
        let k = start + off;
        let before_ok = k == 0 || !(cb[k - 1].is_ascii_alphanumeric() || cb[k - 1] == b'_');
        let end = k + pat.len();
        let after_ok = end >= cb.len() || !(cb[end].is_ascii_alphanumeric() || cb[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
        start = k + 1;
    }
    false
}

/// Lint one file's source. `rel` is the repo-relative path used both for
/// diagnostics and for rule scoping.
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let lines = split_lines(src);
    let tests = test_regions(&lines);
    let scope = scope_of(rel);
    let mut out = Vec::new();

    let mut push = |idx: usize, rule: &'static str, line: &Line| {
        out.push(Violation {
            file: rel.to_string(),
            line: idx + 1,
            rule,
            snippet: snippet(&line.code),
        });
    };

    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;

        // Rule 1: undocumented unsafe. Everywhere, tests included.
        if has_token(code, "unsafe") && !annotated(&lines, idx, SAFETY_TAGS) {
            push(idx, "undocumented-unsafe", line);
        }

        if tests[idx] {
            continue;
        }

        // Rule 2: truncating casts in quant/ and model/.
        if (scope.quant || scope.model)
            && CAST_PATTERNS.iter().any(|p| has_cast(code, p))
            && !code.contains("clamp(")
            && !annotated(&lines, idx, CLAMPED_TAGS)
        {
            push(idx, "unclamped-cast", line);
        }

        // Rule 3: panic paths in serve/.
        if scope.serve
            && PANIC_PATTERNS.iter().any(|p| code.contains(p))
            && !annotated(&lines, idx, PANIC_OK_TAGS)
        {
            push(idx, "serve-panic-path", line);
        }

        // Rule 4: nondeterminism hazards.
        if scope.quant || scope.model || scope.serve {
            if code.contains("std::collections::")
                && (has_token(code, "HashMap") || has_token(code, "HashSet"))
                && !annotated(&lines, idx, DETERMINISM_TAGS)
            {
                push(idx, "nondet-hash-iteration", line);
            }
            if RNG_TOKENS.iter().any(|t| has_token(code, t))
                && !annotated(&lines, idx, DETERMINISM_TAGS)
            {
                push(idx, "nondet-rng", line);
            }
        }
        if (scope.quant || scope.model || scope.obs)
            && CLOCK_TOKENS.iter().any(|t| has_token(code, t))
            && !annotated(&lines, idx, DETERMINISM_TAGS)
        {
            push(idx, "nondet-clock", line);
        }
    }
    out
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// output, skipping build artifacts.
pub fn rust_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = std::fs::read_dir(&dir)?
            .collect::<Result<Vec<_>, _>>()?
            .into_iter()
            .map(|e| e.path())
            .collect();
        entries.sort();
        for path in entries {
            if path.is_dir() {
                let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
                if name != "target" {
                    stack.push(path);
                }
            } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `.rs` file under each root. Diagnostic paths are reported
/// relative to `base` (typically the `rust/` workspace dir).
pub fn lint_tree(base: &Path, roots: &[PathBuf]) -> std::io::Result<Vec<Violation>> {
    let mut all = Vec::new();
    for root in roots {
        for path in rust_files(root)? {
            let rel = path.strip_prefix(base).unwrap_or(&path);
            let rel = rel.to_string_lossy().replace('\\', "/");
            let src = std::fs::read_to_string(&path)?;
            all.extend(lint_source(&rel, &src));
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unsafe_without_safety_flagged() {
        let v = lint_source("src/util/x.rs", "fn f() {\n    unsafe { g() }\n}\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "undocumented-unsafe");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_with_safety_clean() {
        let v = lint_source(
            "src/util/x.rs",
            "fn f() {\n    // SAFETY: g has no preconditions here\n    unsafe { g() }\n}\n",
        );
        assert!(v.is_empty());
    }

    #[test]
    fn unsafe_in_string_not_flagged() {
        let v = lint_source("src/util/x.rs", "fn f() { let s = \"unsafe\"; }\n");
        assert!(v.is_empty());
    }

    #[test]
    fn cast_rules_scoped_to_quant_and_model() {
        let src = "fn f(x: u32) -> u8 { x as u8 }\n";
        assert_eq!(lint_source("src/quant/x.rs", src).len(), 1);
        assert_eq!(lint_source("src/model/x.rs", src).len(), 1);
        assert!(lint_source("src/util/x.rs", src).is_empty());
        assert!(lint_source("src/serve/x.rs", src).is_empty());
    }

    #[test]
    fn cast_with_clamp_or_annotation_clean() {
        let clamped = "fn f(x: f32) -> u8 { x.clamp(0.0, 255.0) as u8 }\n";
        assert!(lint_source("src/quant/x.rs", clamped).is_empty());
        let ann = "fn f(x: u32) -> u8 {\n    // CLAMPED: caller masks\n    x as u8\n}\n";
        assert!(lint_source("src/quant/x.rs", ann).is_empty());
    }

    #[test]
    fn cast_token_boundary() {
        // `as usize` must not match the `as u8`-style patterns; identifiers
        // ending in the pattern must not match either.
        let v = lint_source("src/quant/x.rs", "let y = x as usize;\n");
        assert!(v.is_empty());
    }

    #[test]
    fn serve_panics_flagged_unless_panic_ok() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let v = lint_source("src/serve/x.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "serve-panic-path");
        let ok = "fn f() -> u32 {\n    // PANIC-OK: admit() rejects None\n    x.unwrap()\n}\n";
        assert!(lint_source("src/serve/x.rs", ok).is_empty());
    }

    #[test]
    fn serve_test_code_exempt_from_panic_rule() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
        assert!(lint_source("src/serve/x.rs", src).is_empty());
    }

    #[test]
    fn hashmap_import_needs_determinism_note() {
        let bad = "use std::collections::HashMap;\n";
        let v = lint_source("src/model/x.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "nondet-hash-iteration");
        let ok = "// DETERMINISM: keyed lookups only\nuse std::collections::HashMap;\n";
        assert!(lint_source("src/model/x.rs", ok).is_empty());
        // BTreeMap is always fine.
        assert!(lint_source("src/model/x.rs", "use std::collections::BTreeMap;\n").is_empty());
    }

    #[test]
    fn clocks_banned_in_kernels_not_serve() {
        let src = "use std::time::Instant;\n";
        assert_eq!(lint_source("src/quant/x.rs", src).len(), 1);
        assert_eq!(lint_source("src/model/x.rs", src).len(), 1);
        // serve/ telemetry legitimately uses wall clocks.
        assert!(lint_source("src/serve/x.rs", src).is_empty());
    }

    #[test]
    fn clocks_in_obs_need_determinism_note() {
        // obs/ is called from bit-identity paths, so every clock read
        // there carries the same justification burden as quant/model.
        let src = "use std::time::Instant;\n";
        let v = lint_source("src/obs/x.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "nondet-clock");
        let ok = "// DETERMINISM: timestamp feeds telemetry only, never values\nuse std::time::Instant;\n";
        assert!(lint_source("src/obs/x.rs", ok).is_empty());
    }

    #[test]
    fn ambient_rng_flagged() {
        let v = lint_source("src/quant/x.rs", "let mut r = thread_rng();\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "nondet-rng");
    }

    #[test]
    fn empty_justification_is_a_violation() {
        let src = "// PANIC-OK:\nfn f() { x.unwrap(); }\n";
        let v = lint_source("src/serve/x.rs", src);
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn display_has_file_line() {
        let v = lint_source("src/serve/x.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n");
        let s = v[0].to_string();
        assert!(s.starts_with("src/serve/x.rs:1:"), "{s}");
    }
}

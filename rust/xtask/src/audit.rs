//! `cargo xtask audit` — measure the unsafe/panic/cast surface and compare
//! it against the committed `rust/AUDIT.json` baseline.
//!
//! The report is hand-rendered JSON with globally-unique top-level scalar
//! keys so `--check-baseline` can extract integers with a string scan
//! instead of a JSON parser (this crate is dependency-free by design).
//! Baseline comparison is directional: surface *counts* may shrink freely
//! but may not grow past the committed numbers, and coverage invariants
//! (every unsafe annotated, every serve panic site justified, every
//! quant/model cast clamped) must hold exactly.

use crate::lexer::{annotated, has_token, split_lines, test_regions};
use crate::lint::{
    has_cast, lint_source, rust_files, scope_of, CLAMPED_TAGS, DETERMINISM_TAGS, PANIC_OK_TAGS,
    SAFETY_TAGS,
};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

const PANIC_PATTERNS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];
const CAST_PATTERNS: &[&str] = &["as u8", "as u16", "as i8"];

#[derive(Debug, Default, Clone)]
pub struct FileStats {
    pub unsafe_sites: u64,
    pub panic_sites: u64,
}

#[derive(Debug, Default)]
pub struct Audit {
    pub unsafe_total: u64,
    pub unsafe_safety_annotated: u64,
    pub serve_panic_sites: u64,
    pub serve_panic_ok: u64,
    pub clamped_casts: u64,
    pub casts_unjustified: u64,
    pub determinism_notes: u64,
    pub lint_violations: u64,
    pub per_file: BTreeMap<String, FileStats>,
}

impl Audit {
    pub fn serve_panic_reachable(&self) -> u64 {
        self.serve_panic_sites - self.serve_panic_ok
    }
    pub fn unsafe_unannotated(&self) -> u64 {
        self.unsafe_total - self.unsafe_safety_annotated
    }
}

/// Scan the tree and compute the audit counters.
pub fn audit_tree(base: &Path, roots: &[PathBuf]) -> std::io::Result<Audit> {
    let mut a = Audit::default();
    for root in roots {
        for path in rust_files(root)? {
            let rel = path.strip_prefix(base).unwrap_or(&path);
            let rel = rel.to_string_lossy().replace('\\', "/");
            let src = std::fs::read_to_string(&path)?;
            let lines = split_lines(&src);
            let tests = test_regions(&lines);
            let scope = scope_of(&rel);
            let mut fs = FileStats::default();

            for (idx, line) in lines.iter().enumerate() {
                let code = &line.code;
                if has_token(code, "unsafe") {
                    a.unsafe_total += 1;
                    fs.unsafe_sites += 1;
                    if annotated(&lines, idx, SAFETY_TAGS) {
                        a.unsafe_safety_annotated += 1;
                    }
                }
                if tests[idx] {
                    continue;
                }
                if scope.serve && PANIC_PATTERNS.iter().any(|p| code.contains(p)) {
                    a.serve_panic_sites += 1;
                    fs.panic_sites += 1;
                    if annotated(&lines, idx, PANIC_OK_TAGS) {
                        a.serve_panic_ok += 1;
                    }
                }
                let casty = CAST_PATTERNS.iter().any(|p| has_cast(code, p));
                if (scope.quant || scope.model) && casty {
                    if code.contains("clamp(") || annotated(&lines, idx, CLAMPED_TAGS) {
                        a.clamped_casts += 1;
                    } else {
                        a.casts_unjustified += 1;
                    }
                }
                if (scope.quant || scope.model || scope.serve)
                    && annotated(&lines, idx, DETERMINISM_TAGS)
                    && code.contains("std::collections::")
                {
                    a.determinism_notes += 1;
                }
            }
            if fs.unsafe_sites > 0 || fs.panic_sites > 0 {
                a.per_file.insert(rel.clone(), fs);
            }
            a.lint_violations += lint_source(&rel, &src).len() as u64;
        }
    }
    Ok(a)
}

/// Render the audit as stable, diff-friendly JSON.
pub fn render_json(a: &Audit) -> String {
    let kv = |k: &str, v: u64| format!("  \"{k}\": {v},\n");
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": 1,\n");
    s.push_str(&kv("unsafe_total", a.unsafe_total));
    s.push_str(&kv("unsafe_safety_annotated", a.unsafe_safety_annotated));
    s.push_str(&kv("unsafe_unannotated", a.unsafe_unannotated()));
    s.push_str(&kv("serve_panic_sites", a.serve_panic_sites));
    s.push_str(&kv("serve_panic_ok", a.serve_panic_ok));
    s.push_str(&kv("serve_panic_reachable", a.serve_panic_reachable()));
    s.push_str(&kv("clamped_casts", a.clamped_casts));
    s.push_str(&kv("casts_unjustified", a.casts_unjustified));
    s.push_str(&kv("determinism_notes", a.determinism_notes));
    s.push_str(&kv("lint_violations", a.lint_violations));
    s.push_str("  \"per_file\": {\n");
    let n = a.per_file.len();
    for (i, (file, fs)) in a.per_file.iter().enumerate() {
        let (u, p) = (fs.unsafe_sites, fs.panic_sites);
        let sep = if i + 1 < n { "," } else { "" };
        s.push_str(&format!(
            "    \"{file}\": {{ \"unsafe\": {u}, \"panic\": {p} }}{sep}\n"
        ));
    }
    s.push_str("  },\n");
    // Dynamic-analysis clean bill. Maintained by hand when the nightly
    // verify workflow (.github/workflows/verify.yml) changes status; the
    // static counters above are regenerated by `cargo xtask audit --write`.
    let dynamic = [
        ("miri", "clean: util::pool + scalar quant::packed, weekly"),
        ("asan", "clean: pool + scheduler + packed test suites"),
        ("tsan", "clean: pool + scheduler test suites"),
        ("loom", "clean: pool partitioning + cancel registry models"),
    ];
    s.push_str("  \"dynamic\": {\n");
    let m = dynamic.len();
    for (i, (k, v)) in dynamic.iter().enumerate() {
        let sep = if i + 1 < m { "," } else { "" };
        s.push_str(&format!("    \"{k}\": \"{v}\"{sep}\n"));
    }
    s.push_str("  }\n");
    s.push_str("}\n");
    s
}

/// Extract an integer value for a top-level scalar key from rendered JSON.
/// Keys are globally unique by construction, so a string scan suffices.
pub fn extract_int(json: &str, key: &str) -> Option<u64> {
    let needle = format!("\"{key}\":");
    let k = json.find(&needle)?;
    let rest = json[k + needle.len()..].trim_start();
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Compare a freshly-computed audit against the committed baseline.
/// Returns a list of human-readable failures (empty = pass).
pub fn check_baseline(computed: &Audit, baseline_json: &str) -> Vec<String> {
    let mut fails = Vec::new();
    // Coverage invariants: exact, independent of the baseline.
    let unsafe_bare = computed.unsafe_unannotated();
    if unsafe_bare != 0 {
        fails.push(format!("{unsafe_bare} unsafe site(s) lack a SAFETY justification"));
    }
    let panics_bare = computed.serve_panic_reachable();
    if panics_bare != 0 {
        fails.push(format!("{panics_bare} serve/ panic site(s) lack a PANIC-OK justification"));
    }
    let casts_bare = computed.casts_unjustified;
    if casts_bare != 0 {
        fails.push(format!("{casts_bare} cast site(s) lack a clamp or CLAMPED justification"));
    }
    let lints = computed.lint_violations;
    if lints != 0 {
        fails.push(format!("{lints} lint violation(s); run `cargo xtask lint`"));
    }
    // Directional surface ceilings vs the committed baseline: shrinking is
    // free, growth demands a deliberate `cargo xtask audit --write`.
    for (key, value) in [
        ("unsafe_total", computed.unsafe_total),
        ("serve_panic_sites", computed.serve_panic_sites),
    ] {
        match extract_int(baseline_json, key) {
            Some(base) if value > base => {
                fails.push(format!("{key} grew {base} -> {value}; re-baseline if intended"));
            }
            Some(_) => {}
            None => fails.push(format!("baseline AUDIT.json missing key {key}")),
        }
    }
    fails
}

#[cfg(test)]
mod tests {
    use super::*;

    fn audit_of(rel: &str, src: &str) -> Audit {
        let mut a = Audit::default();
        let lines = split_lines(src);
        let tests = test_regions(&lines);
        let scope = scope_of(rel);
        for (idx, line) in lines.iter().enumerate() {
            let code = &line.code;
            if has_token(code, "unsafe") {
                a.unsafe_total += 1;
                if annotated(&lines, idx, SAFETY_TAGS) {
                    a.unsafe_safety_annotated += 1;
                }
            }
            if tests[idx] {
                continue;
            }
            if scope.serve && PANIC_PATTERNS.iter().any(|p| code.contains(p)) {
                a.serve_panic_sites += 1;
                if annotated(&lines, idx, PANIC_OK_TAGS) {
                    a.serve_panic_ok += 1;
                }
            }
        }
        a.lint_violations += lint_source(rel, src).len() as u64;
        a
    }

    #[test]
    fn counts_unsafe_and_annotations() {
        let src = "// SAFETY: fine\nunsafe { a() }\nunsafe { b() }\n";
        let a = audit_of("src/util/x.rs", src);
        assert_eq!(a.unsafe_total, 2);
        assert_eq!(a.unsafe_safety_annotated, 1);
        assert_eq!(a.unsafe_unannotated(), 1);
    }

    #[test]
    fn render_and_extract_roundtrip() {
        let a = Audit {
            unsafe_total: 10,
            unsafe_safety_annotated: 10,
            serve_panic_sites: 3,
            serve_panic_ok: 3,
            ..Default::default()
        };
        let json = render_json(&a);
        assert_eq!(extract_int(&json, "unsafe_total"), Some(10));
        assert_eq!(extract_int(&json, "serve_panic_sites"), Some(3));
        assert_eq!(extract_int(&json, "serve_panic_reachable"), Some(0));
        assert_eq!(extract_int(&json, "missing_key"), None);
    }

    #[test]
    fn baseline_blocks_growth_but_allows_shrink() {
        let mut a = Audit {
            unsafe_total: 4,
            unsafe_safety_annotated: 4,
            serve_panic_sites: 1,
            serve_panic_ok: 1,
            ..Default::default()
        };
        let baseline = render_json(&Audit {
            unsafe_total: 4,
            unsafe_safety_annotated: 4,
            serve_panic_sites: 2,
            serve_panic_ok: 2,
            ..Default::default()
        });
        assert!(check_baseline(&a, &baseline).is_empty());
        a.unsafe_total = 5;
        a.unsafe_safety_annotated = 5;
        let fails = check_baseline(&a, &baseline);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("unsafe_total grew"));
    }

    #[test]
    fn baseline_requires_full_coverage() {
        let a = Audit { unsafe_total: 2, unsafe_safety_annotated: 1, ..Default::default() };
        let baseline = render_json(&a);
        let fails = check_baseline(&a, &baseline);
        assert!(fails.iter().any(|f| f.contains("SAFETY")));
    }
}

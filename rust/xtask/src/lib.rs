//! Repo-native static analysis for the invarexplore workspace.
//!
//! Run as `cargo xtask lint` / `cargo xtask audit` (alias in
//! `rust/.cargo/config.toml`). See the README "Correctness tooling"
//! section for the rule catalogue and annotation grammar.

pub mod audit;
pub mod envdoc;
pub mod lexer;
pub mod lint;
pub mod mdlint;

use std::path::PathBuf;

/// The `rust/` workspace directory (parent of this crate).
pub fn workspace_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives inside the workspace")
        .to_path_buf()
}

/// Default lint/audit roots, relative to the workspace dir. `xtask/tests`
/// is excluded on purpose: it holds fixture files with seeded violations.
pub fn default_roots() -> Vec<PathBuf> {
    let base = workspace_root();
    ["src", "benches", "xla-stub/src", "xtask/src"]
        .iter()
        .map(|r| base.join(r))
        .filter(|p| p.is_dir())
        .collect()
}

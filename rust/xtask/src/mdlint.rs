//! `cargo xtask mdlint` — hygiene for the operator-facing markdown.
//!
//! Two rules over `README.md`, `CONTRIBUTING.md` and everything under
//! `docs/`:
//!
//! * `untagged-code-fence` — every *opening* ``` fence must name a
//!   language (` ```sh `, ` ```text `, …) so renderers highlight and
//!   tooling can extract runnable blocks;
//! * `dead-relative-link` — every relative `[text](path)` target must
//!   exist on disk, resolved against the document's own directory
//!   (fragments are stripped; `http(s)://`, `mailto:` and `#anchor`
//!   links are out of scope).
//!
//! Link targets inside fenced code blocks are ignored.

use std::path::{Path, PathBuf};

use crate::lint::Violation;

/// The documents checked by default: repo README, CONTRIBUTING, and
/// every `.md` under `docs/`, sorted for deterministic output.
pub fn default_docs() -> Vec<PathBuf> {
    let repo = repo_root();
    let mut docs = vec![repo.join("README.md"), repo.join("CONTRIBUTING.md")];
    if let Ok(rd) = std::fs::read_dir(repo.join("docs")) {
        let mut extra: Vec<PathBuf> = rd
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("md"))
            .collect();
        extra.sort();
        docs.extend(extra);
    }
    docs
}

/// Repo root: one level above the cargo workspace.
pub fn repo_root() -> PathBuf {
    match crate::workspace_root().parent() {
        Some(repo) => repo.to_path_buf(),
        None => PathBuf::from("."),
    }
}

/// Check one markdown document. `rel` is the diagnostic path; `dir` is
/// the directory relative links resolve against.
pub fn check_markdown(rel: &str, text: &str, dir: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for (idx, line) in text.lines().enumerate() {
        let t = line.trim_start();
        if t.starts_with("```") {
            if !in_fence && t.trim_start_matches('`').trim().is_empty() {
                out.push(violation(rel, idx, "untagged-code-fence", t));
            }
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        for target in link_targets(line) {
            if !is_relative(target) {
                continue;
            }
            // strip the fragment: `docs/X.md#section` checks `docs/X.md`
            let path = target.split(['#', '?']).next().unwrap_or("");
            if !path.is_empty() && !dir.join(path).exists() {
                out.push(violation(rel, idx, "dead-relative-link", target));
            }
        }
    }
    out
}

/// Check every document in `docs`; diagnostic paths are repo-relative.
pub fn check_docs(docs: &[PathBuf]) -> std::io::Result<Vec<Violation>> {
    let repo = repo_root();
    let mut all = Vec::new();
    for doc in docs {
        let rel = doc.strip_prefix(&repo).unwrap_or(doc);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let text = std::fs::read_to_string(doc)?;
        let dir = doc.parent().unwrap_or(Path::new("."));
        all.extend(check_markdown(&rel, &text, dir));
    }
    Ok(all)
}

fn violation(rel: &str, idx: usize, rule: &'static str, snippet: &str) -> Violation {
    let mut s: String = snippet.trim().chars().take(60).collect();
    if snippet.trim().chars().count() > 60 {
        s.push_str("...");
    }
    Violation { file: rel.to_string(), line: idx + 1, rule, snippet: s }
}

/// Every `](target)` on the line, in order.
fn link_targets(line: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(off) = line[start..].find("](") {
        let open = start + off + 2;
        match line[open..].find(')') {
            Some(close) => {
                out.push(line[open..open + close].trim());
                start = open + close + 1;
            }
            None => break,
        }
    }
    out
}

fn is_relative(target: &str) -> bool {
    !(target.is_empty()
        || target.starts_with('#')
        || target.starts_with("http://")
        || target.starts_with("https://")
        || target.starts_with("mailto:"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untagged_opening_fence_flagged_closing_not() {
        let md = "intro\n```\ncode\n```\n\n```sh\nls\n```\n";
        let v = check_markdown("X.md", md, Path::new("."));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "untagged-code-fence");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn dead_relative_link_flagged_with_target() {
        let md = "see [the plan](no/such/file.md) for details\n";
        let v = check_markdown("X.md", md, &repo_root());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "dead-relative-link");
        assert_eq!(v[0].snippet, "no/such/file.md");
    }

    #[test]
    fn live_relative_link_and_fragment_clean() {
        let md = "[crate](rust/src/lib.rs) and [same](rust/src/lib.rs#L1)\n";
        assert!(check_markdown("X.md", md, &repo_root()).is_empty());
    }

    #[test]
    fn absolute_and_anchor_links_out_of_scope() {
        let md = "[a](https://example.com/x.md) [b](#section) [c](mailto:x@y.z)\n";
        assert!(check_markdown("X.md", md, Path::new("/nonexistent")).is_empty());
    }

    #[test]
    fn links_inside_fences_ignored() {
        let md = "```text\n[not a link](missing.md)\n```\n";
        assert!(check_markdown("X.md", md, Path::new("/nonexistent")).is_empty());
    }

    #[test]
    fn two_links_on_one_line_both_checked() {
        let md = "[a](gone1.md) then [b](gone2.md)\n";
        let v = check_markdown("X.md", md, Path::new("/nonexistent"));
        assert_eq!(v.len(), 2);
        assert_eq!(v[1].snippet, "gone2.md");
    }
}

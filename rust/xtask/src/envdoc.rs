//! `cargo xtask envdoc` — the operator-surface documentation lint.
//!
//! Every environment variable the crate reads (`std::env::var`, the
//! `util::cli::env_parse` / `env_override` wrappers) must appear,
//! backticked, in the repo README's env-knob table. The check is
//! lexical, like the other xtask lints:
//!
//! * env-read call sites are located on the lexer's *code* channel (so
//!   the tokens never match inside strings or comments), but the
//!   variable name is extracted from the *raw* source line — the lexer
//!   blanks string-literal contents;
//! * `#[cfg(test)]` regions are exempt (tests may invent scratch
//!   variables);
//! * a site that cannot name a literal variable — the generic wrappers
//!   themselves, or a read through a runtime-computed name — must carry
//!   a per-site `// ENV-DOC: <why>` justification.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::lexer::{annotated, split_lines, test_regions};
use crate::lint::{rust_files, Violation};

/// Tags that exempt a single env-read site from the README requirement.
pub const ENV_DOC_TAGS: &[&str] = &["ENV-DOC:"];

/// Tokens that read the process environment. A match must be followed
/// by a call — `(` or a turbofish `::<` — so `use ...::env_override;`
/// imports are not sites.
const READ_TOKENS: &[&str] = &["env::var", "env::var_os", "env_parse", "env_override"];

/// Repo README holding the authoritative env-knob table (one level above
/// the cargo workspace).
pub fn readme_path() -> PathBuf {
    match crate::workspace_root().parent() {
        Some(repo) => repo.join("README.md"),
        None => PathBuf::from("README.md"),
    }
}

/// Roots scanned by default: the crate sources and the bench drivers.
/// xtask itself reads no tuning knobs, so it is not in scope.
pub fn default_roots() -> Vec<PathBuf> {
    let ws = crate::workspace_root();
    vec![ws.join("src"), ws.join("benches")]
}

/// Collect the documented variable names: every backticked span in the
/// README whose leading token looks like an env-var name
/// (`ALL_CAPS_WITH_UNDERSCORES`, optionally followed by `=value` or a
/// space inside the same span).
pub fn documented_vars(readme: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for (i, span) in readme.split('`').enumerate() {
        if i % 2 == 1 {
            if let Some(name) = env_name_prefix(span) {
                out.insert(name.to_string());
            }
        }
    }
    out
}

/// The leading `[A-Z][A-Z0-9_]*` run of `span`, accepted as an env-var
/// name when it contains an underscore and the span continues (if at
/// all) with `=` or a space — so `INVAREXPLORE_SIMD=scalar` documents
/// `INVAREXPLORE_SIMD` while `BENCH_<suite>.json` documents nothing.
fn env_name_prefix(span: &str) -> Option<&str> {
    let end = span
        .bytes()
        .position(|b| !(b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_'))
        .unwrap_or(span.len());
    let name = &span[..end];
    let sound = name.len() >= 3
        && name.contains('_')
        && name.as_bytes()[0].is_ascii_uppercase()
        && matches!(span.as_bytes().get(end), None | Some(b'=') | Some(b' '));
    sound.then_some(name)
}

/// First env-read call token on the code channel.
fn read_site(code: &str) -> Option<&'static str> {
    let mut best: Option<(usize, &'static str)> = None;
    for t in READ_TOKENS {
        if let Some(k) = find_call(code, t) {
            let better = match best {
                None => true,
                Some((bk, _)) => k < bk,
            };
            if better {
                best = Some((k, t));
            }
        }
    }
    best.map(|(_, t)| t)
}

/// First occurrence of `tok` in `code` that is identifier-bounded on both
/// sides (so `remove_var` / `my_env_parse` never match) and followed by a
/// call: `(` directly or through a turbofish `::<`.
fn find_call(code: &str, tok: &str) -> Option<usize> {
    let cb = code.as_bytes();
    let mut start = 0usize;
    while let Some(off) = code[start..].find(tok) {
        let k = start + off;
        let end = k + tok.len();
        let before_ok = k == 0 || {
            let b = cb[k - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let rest = &code[end..];
        let is_call = rest.starts_with('(') || rest.starts_with("::<");
        if before_ok && is_call {
            return Some(k);
        }
        start = k + 1;
    }
    None
}

/// The literal variable name passed to `token` on the raw source line:
/// the contents of the first `"..."` after the call token, accepted only
/// when it is shaped like an env-var name. `None` means the site reads
/// through a runtime-computed name.
fn literal_name<'a>(raw: &'a str, token: &str) -> Option<&'a str> {
    let from = raw.find(token)? + token.len();
    let rest = raw.get(from..)?;
    let open = rest.find('"')?;
    let body = &rest[open + 1..];
    let name = &body[..body.find('"')?];
    let sound = !name.is_empty()
        && name.as_bytes()[0].is_ascii_uppercase()
        && name
            .bytes()
            .all(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_');
    sound.then_some(name)
}

/// Check one file's source against the documented-name set. `rel` is the
/// diagnostic path.
pub fn check_source(rel: &str, src: &str, documented: &BTreeSet<String>) -> Vec<Violation> {
    let lines = split_lines(src);
    let tests = test_regions(&lines);
    let raw: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if tests[idx] {
            continue;
        }
        let Some(token) = read_site(&line.code) else {
            continue;
        };
        if annotated(&lines, idx, ENV_DOC_TAGS) {
            continue;
        }
        match raw.get(idx).and_then(|r| literal_name(r, token)) {
            Some(name) if documented.contains(name) => {}
            Some(name) => out.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "undocumented-env-knob",
                snippet: name.to_string(),
            }),
            None => out.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "unnamed-env-read",
                snippet: line.code.trim().chars().take(60).collect(),
            }),
        }
    }
    out
}

/// Check every `.rs` file under each root. Diagnostic paths are reported
/// relative to `base` (typically the `rust/` workspace dir).
pub fn check_tree(
    base: &Path,
    roots: &[PathBuf],
    documented: &BTreeSet<String>,
) -> std::io::Result<Vec<Violation>> {
    let mut all = Vec::new();
    for root in roots {
        for path in rust_files(root)? {
            let rel = path.strip_prefix(base).unwrap_or(&path);
            let rel = rel.to_string_lossy().replace('\\', "/");
            let src = std::fs::read_to_string(&path)?;
            all.extend(check_source(&rel, &src, documented));
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn docs(names: &[&str]) -> BTreeSet<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn table_rows_and_value_spans_document_names() {
        let readme = "| `--batch K` / `INVAREXPLORE_BATCH` | per round | `1` |\n\
                      Override with `INVAREXPLORE_SIMD=scalar|sse2|avx2`.\n\
                      `SERVE_SPEC=k` turns on speculation; `KV_PAGE = 16`.\n";
        let d = documented_vars(readme);
        assert!(d.contains("INVAREXPLORE_BATCH"));
        assert!(d.contains("INVAREXPLORE_SIMD"));
        assert!(d.contains("SERVE_SPEC"));
        assert!(d.contains("KV_PAGE"));
    }

    #[test]
    fn artifact_names_and_prose_do_not_document() {
        let readme = "uploads `BENCH_<suite>.json`; see `TokenSink` and `CI`.\n";
        assert!(documented_vars(readme).is_empty());
    }

    #[test]
    fn documented_read_is_clean() {
        let src = "fn f() -> bool {\n    std::env::var(\"SERVE_SMOKE\").is_ok()\n}\n";
        assert!(check_source("src/x.rs", src, &docs(&["SERVE_SMOKE"])).is_empty());
    }

    #[test]
    fn undocumented_read_flagged_with_name_and_line() {
        let src = "fn f() -> bool {\n    std::env::var(\"SERVE_SMOKE\").is_ok()\n}\n";
        let v = check_source("src/x.rs", src, &docs(&[]));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "undocumented-env-knob");
        assert_eq!(v[0].line, 2);
        assert_eq!(v[0].snippet, "SERVE_SMOKE");
    }

    #[test]
    fn wrapper_calls_are_in_scope() {
        let src = "fn f() -> usize {\n    env_override(\"SERVE_KNOB\", 1usize)\n}\n";
        let v = check_source("src/x.rs", src, &docs(&[]));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].snippet, "SERVE_KNOB");
    }

    #[test]
    fn dynamic_name_needs_env_doc_tag() {
        let bad = "pub fn get(name: &str) -> Option<String> {\n    std::env::var(name).ok()\n}\n";
        let v = check_source("src/x.rs", bad, &docs(&[]));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "unnamed-env-read");
        let ok = "pub fn get(name: &str) -> Option<String> {\n    \
                  // ENV-DOC: generic accessor; callers name the knob\n    \
                  std::env::var(name).ok()\n}\n";
        assert!(check_source("src/x.rs", ok, &docs(&[])).is_empty());
    }

    #[test]
    fn empty_env_doc_justification_rejected() {
        let src = "fn f() {\n    // ENV-DOC:\n    let _ = std::env::var(\"SERVE_X\");\n}\n";
        assert_eq!(check_source("src/x.rs", src, &docs(&[])).len(), 1);
    }

    #[test]
    fn test_regions_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let _ = std::env::var(\"SCRATCH_VAR\"); }\n}\n";
        assert!(check_source("src/x.rs", src, &docs(&[])).is_empty());
    }

    #[test]
    fn token_inside_string_not_a_site() {
        let src = "fn f() { let s = \"std::env::var(FOO_BAR)\"; }\n";
        assert!(check_source("src/x.rs", src, &docs(&[])).is_empty());
    }

    #[test]
    fn turbofish_call_is_a_site() {
        let src = "fn f() -> Option<usize> {\n    \
                   crate::util::cli::env_parse::<usize>(\"SERVE_TURBO\")\n}\n";
        let v = check_source("src/x.rs", src, &docs(&[]));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].snippet, "SERVE_TURBO");
        assert!(check_source("src/x.rs", src, &docs(&["SERVE_TURBO"])).is_empty());
    }

    #[test]
    fn use_import_is_not_a_site() {
        let src = "use crate::util::cli::env_override;\n";
        assert!(check_source("src/x.rs", src, &docs(&[])).is_empty());
    }

    #[test]
    fn remove_and_set_var_not_sites() {
        let src = "fn f() { std::env::remove_var(\"A_B\"); std::env::set_var(\"A_B\", \"1\"); }\n";
        assert!(check_source("src/x.rs", src, &docs(&[])).is_empty());
    }
}

//! Integration tests for `cargo xtask lint` against seeded fixture trees.
//!
//! `tests/fixtures/bad` contains one file per rule with a violation at a
//! known line; `tests/fixtures/clean` contains annotated/clamped
//! equivalents that must produce zero diagnostics. The fixtures live
//! under `tests/fixtures/` (not `tests/*.rs`) so cargo never compiles
//! them, and the default lint roots exclude them so the real tree stays
//! clean.

use std::path::PathBuf;
use xtask::lint::{lint_tree, Violation};

fn fixture_root(which: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(which)
}

fn lint_fixture(which: &str) -> Vec<Violation> {
    let base = fixture_root(which);
    lint_tree(&base, &[base.join("src")]).expect("fixture tree readable")
}

fn assert_reported(violations: &[Violation], file: &str, line: usize, rule: &str) {
    assert!(
        violations
            .iter()
            .any(|v| v.file == file && v.line == line && v.rule == rule),
        "expected {file}:{line} [{rule}] in {violations:#?}"
    );
}

#[test]
fn seeded_unclamped_cast_reported_with_file_line() {
    let v = lint_fixture("bad");
    assert_reported(&v, "src/quant/bad_cast.rs", 4, "unclamped-cast");
}

#[test]
fn seeded_serve_panic_reported_with_file_line() {
    let v = lint_fixture("bad");
    assert_reported(&v, "src/serve/bad_panic.rs", 4, "serve-panic-path");
}

#[test]
fn seeded_nondeterminism_reported_with_file_line() {
    let v = lint_fixture("bad");
    assert_reported(&v, "src/model/bad_nondet.rs", 3, "nondet-hash-iteration");
    assert_reported(&v, "src/model/bad_nondet.rs", 4, "nondet-clock");
    assert_reported(&v, "src/model/bad_nondet.rs", 8, "nondet-clock");
}

#[test]
fn seeded_undocumented_unsafe_reported_with_file_line() {
    let v = lint_fixture("bad");
    assert_reported(&v, "src/util/bad_unsafe.rs", 4, "undocumented-unsafe");
}

#[test]
fn bad_fixture_has_exactly_the_seeded_violations() {
    let v = lint_fixture("bad");
    assert_eq!(v.len(), 6, "unexpected extra violations: {v:#?}");
}

#[test]
fn clean_fixture_lints_clean() {
    let v = lint_fixture("clean");
    assert!(v.is_empty(), "clean fixtures must not lint: {v:#?}");
}

#[test]
fn diagnostics_render_as_path_line_rule() {
    let v = lint_fixture("bad");
    let rendered = v.iter().map(|x| x.to_string()).collect::<Vec<_>>();
    assert!(rendered
        .iter()
        .any(|s| s.starts_with("src/quant/bad_cast.rs:4: [unclamped-cast]")));
}

#[test]
fn real_tree_lints_clean() {
    // The acceptance bar for this whole subsystem: the shipped tree has a
    // justification at every invariant site and zero blanket exemptions.
    let base = xtask::workspace_root();
    let v = lint_tree(&base, &xtask::default_roots()).expect("workspace readable");
    assert!(v.is_empty(), "workspace must lint clean: {v:#?}");
}

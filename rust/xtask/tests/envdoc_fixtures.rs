//! Integration tests for `cargo xtask envdoc` and `cargo xtask mdlint`.
//!
//! The envdoc fixture lives in its own `tests/fixtures/envdoc` tree (not
//! `fixtures/bad`, whose total violation count is pinned) and is the same
//! tree CI's negative check runs the binary against.

use std::path::PathBuf;

use xtask::envdoc;
use xtask::mdlint;

fn real_documented() -> std::collections::BTreeSet<String> {
    let readme = std::fs::read_to_string(envdoc::readme_path()).expect("README readable");
    envdoc::documented_vars(&readme)
}

#[test]
fn readme_table_documents_the_core_knobs() {
    let d = real_documented();
    for name in [
        "INVAREXPLORE_THREADS",
        "INVAREXPLORE_TRACE",
        "INVAREXPLORE_SIMD",
        "SERVE_REPLICAS",
        "SERVE_SHARDS",
        "SERVE_SHED_WATERMARK",
        "PERF_DIFF_THRESHOLD",
    ] {
        assert!(d.contains(name), "README env-knob table is missing `{name}`");
    }
}

#[test]
fn real_tree_envdoc_clean() {
    // The acceptance bar: every env read in src/ and benches/ names a
    // documented knob (or carries a per-site ENV-DOC justification).
    let base = xtask::workspace_root();
    let v = envdoc::check_tree(&base, &envdoc::default_roots(), &real_documented())
        .expect("workspace readable");
    assert!(v.is_empty(), "undocumented env reads: {v:#?}");
}

#[test]
fn seeded_envdoc_fixture_fails_with_file_line() {
    let base = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/envdoc");
    let v = envdoc::check_tree(&base, &[base.join("src")], &real_documented())
        .expect("fixture readable");
    assert_eq!(v.len(), 2, "expected exactly the seeded violations: {v:#?}");
    assert!(v
        .iter()
        .any(|x| x.file == "src/bad_env.rs"
            && x.line == 6
            && x.rule == "undocumented-env-knob"
            && x.snippet == "FIXTURE_UNDOCUMENTED_KNOB"));
    assert!(v
        .iter()
        .any(|x| x.file == "src/bad_env.rs" && x.line == 10 && x.rule == "unnamed-env-read"));
}

#[test]
fn shipped_markdown_is_clean() {
    let docs = mdlint::default_docs();
    assert!(docs.len() >= 3, "expected README, CONTRIBUTING and docs/: {docs:#?}");
    let v = mdlint::check_docs(&docs).expect("docs readable");
    assert!(v.is_empty(), "markdown hygiene violations: {v:#?}");
}

#[test]
fn architecture_doc_is_linked_and_checked() {
    let docs = mdlint::default_docs();
    assert!(
        docs.iter().any(|d| d.ends_with("docs/ARCHITECTURE.md")),
        "docs/ARCHITECTURE.md must be in the default mdlint set: {docs:#?}"
    );
    let readme =
        std::fs::read_to_string(mdlint::repo_root().join("README.md")).expect("README readable");
    assert!(
        readme.contains("docs/ARCHITECTURE.md"),
        "README must link the architecture overview"
    );
}

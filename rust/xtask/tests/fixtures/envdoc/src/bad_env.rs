//! Seeded envdoc violations: knobs the README never documents.
//! Lives outside `fixtures/bad` so the pinned lint-violation count
//! there stays untouched — this tree is only scanned by `envdoc`.

pub fn undocumented_knob() -> bool {
    std::env::var("FIXTURE_UNDOCUMENTED_KNOB").is_ok()
}

pub fn unnamed_read(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

// Seeded violation fixture: panic path in a serve/ request flow.
// Line 4 must be reported as [serve-panic-path].
pub fn lookup(slot: Option<u32>) -> u32 {
    slot.unwrap()
}

// Seeded violation fixture: nondeterminism hazards in a model/ kernel path.
// Line 3: [nondet-hash-iteration]; lines 4 and 8: [nondet-clock].
use std::collections::HashMap;
use std::time::Instant;

pub fn weights() -> HashMap<String, f32> {
    let m = HashMap::new();
    let _t = Instant::now();
    m
}

// Seeded violation fixture: unsafe without a SAFETY justification.
// Line 4 must be reported as [undocumented-unsafe].
pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}

// Seeded violation fixture: unclamped truncating cast in a quant/ path.
// Line 4 must be reported as [unclamped-cast].
pub fn zero_point(z: f32) -> u8 {
    z as u8
}

pub fn fine_clamped(z: f32) -> u8 {
    z.clamp(0.0, 255.0) as u8
}

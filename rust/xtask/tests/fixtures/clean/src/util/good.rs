// Clean fixture: annotated unsafe.
pub fn read_first(p: *const u8, len: usize) -> Option<u8> {
    if len == 0 {
        return None;
    }
    // SAFETY: len > 0 was checked above, so p points to at least one byte.
    Some(unsafe { *p })
}

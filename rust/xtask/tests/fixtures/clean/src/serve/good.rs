// Clean fixture: justified panic site plus a rejection-style flow.
pub fn admit(prompt: &[i32]) -> Result<(), String> {
    if prompt.is_empty() {
        return Err("empty prompt".to_string());
    }
    Ok(())
}

pub fn slot_cache(c: Option<u32>) -> u32 {
    // PANIC-OK: c is Some for every slot admitted by admit()
    c.unwrap()
}

// Clean fixture: every invariant site carries its justification.
use std::collections::BTreeMap;

pub fn zero_point(z: f32) -> u8 {
    z.clamp(0.0, 255.0) as u8
}

pub fn masked(w: u32) -> u8 {
    // CLAMPED: masked to 8 bits on the same expression
    (w & 0xff) as u8
}

pub fn scales() -> BTreeMap<String, f32> {
    BTreeMap::new()
}

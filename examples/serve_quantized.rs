//! Serve a quantized model directly from the packed (deployment) weight
//! format through the continuous-batching scheduler: the model stays
//! bit-packed in RAM (`quant::packed`, 2-bit codes + f16 group scales), the
//! decoder forward runs on the packed codes through the fused
//! unpack→dequant→GEMV kernels, and `serve::Scheduler` admits requests into
//! freed decode slots mid-flight, reuses KV pages across prompts sharing a
//! prefix (radix-trie prefix cache over chunked, refcounted KV pages), and
//! streams tokens through per-request sinks.  Telemetry (TTFT and
//! inter-token latency percentiles, queue depth, prefix-cache hit rate,
//! live KV bytes) is dumped as JSON at the end.
//!
//! ```text
//! cargo run --release --example serve_quantized
//! SERVE_POLICY=spf SERVE_SAMPLER=topk:8:0.7 cargo run --release --example serve_quantized
//! SERVE_ALLOC="2x64,ffn_up=3x64,ffn_down=1x64" cargo run --release --example serve_quantized
//! SERVE_SPEC=4 SERVE_DRAFT_ALLOC=1x64 cargo run --release --example serve_quantized
//! ```
//!
//! `SERVE_ALLOC` takes a mixed-precision [`BitAllocation`] string
//! (`default[,tensor=scheme]*`); the packed model then holds each linear at
//! its allocated width and the fused kernels serve the heterogeneous form
//! directly.  `SERVE_SPEC=k` turns on self-speculative decoding: the same
//! base weights are re-packed at the aggressive `SERVE_DRAFT_ALLOC`
//! (default `1x64`) as a draft model that proposes `k` tokens per round,
//! verified by the target in one chunked forward — completions are
//! bit-identical to `SERVE_SPEC=0`, only faster.
//!
//! `INVAREXPLORE_TRACE=trace.json` turns the recorder on and, at the end,
//! dumps a Chrome trace (load it in `chrome://tracing` / Perfetto to see
//! each request's queue→prefill→decode lifecycle) and prints the
//! Prometheus text rendering of the serve/kernel metrics.

use invarexplore::baselines::{self, Method};
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::Session;
use invarexplore::quant::BitAllocation;
use invarexplore::serve::{AdmissionPolicy, FnSink, Request, Scheduler, ServeOpts};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let model = "opt-small";
    let alloc = match std::env::var("SERVE_ALLOC") {
        Ok(spec) => BitAllocation::parse(&spec)?,
        Err(_) => BitAllocation::parse("2x64")?,
    };
    println!("== serving {model} quantized at allocation {} ==", alloc.label());

    // --- offline: quantize with AWQ and pack ------------------------------
    let w = session.weights(model)?;
    let pile = session.corpus("pile")?;
    let calib = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let prepared = baselines::prepare_mixed(Method::Awq, &alloc, &w, &calib, None)?;
    let quantized = prepared.quantize_model(&prepared.fp, None);
    let pm = prepared.packed_model(&quantized);
    println!(
        "packed model: {:.2} MiB ({:.3} bits/param, {}) for {} linear tensors, served as-is",
        pm.packed_bytes() as f64 / (1 << 20) as f64,
        pm.bits_per_param(),
        pm.bits_summary(),
        pm.n_packed()
    );

    // --- serve: continuous batching with prefix caching + streaming -------
    let batch = 4;
    let n_requests = 8;
    let max_seq = pm.config().max_seq;
    let prompt_len = usize::min(32, max_seq / 2);
    let shared_len = prompt_len / 2; // half the prompt is a shared prefix
    let gen_tokens = 24;
    let wiki = session.corpus("wiki")?;
    anyhow::ensure!(
        wiki.tokens.len() > prompt_len,
        "wiki corpus too small for a {prompt_len}-token prompt"
    );

    // SERVE_SAMPLER overrides decoding for the whole batch (greedy,
    // temp:<t>, topk:<k>[:<t>]); SERVE_POLICY picks admission (fcfs|spf|edf)
    let override_sampler = match std::env::var("SERVE_SAMPLER") {
        Ok(spec) => Some(Sampler::parse(&spec)?),
        Err(_) => None,
    };
    let policy = match std::env::var("SERVE_POLICY") {
        Ok(spec) => AdmissionPolicy::parse(&spec)?,
        Err(_) => AdmissionPolicy::Fcfs,
    };
    // SERVE_SPEC=k: self-speculative decoding with a low-bit draft of the
    // same base weights (SERVE_DRAFT_ALLOC, default 1x64)
    let spec: usize = match std::env::var("SERVE_SPEC") {
        Ok(v) => v.parse().map_err(|_| anyhow::anyhow!("bad SERVE_SPEC {v:?}"))?,
        Err(_) => 0,
    };
    let draft = if spec > 0 {
        let da = BitAllocation::parse(
            &std::env::var("SERVE_DRAFT_ALLOC").unwrap_or_else(|_| "1x64".into()),
        )?;
        let d = pm.draft(&da)?;
        println!(
            "speculative decoding: {spec} draft tokens/round from {} \
             ({:.2} MiB draft next to {:.2} MiB target)",
            da.label(),
            d.packed_bytes() as f64 / (1 << 20) as f64,
            pm.packed_bytes() as f64 / (1 << 20) as f64
        );
        Some(d)
    } else {
        None
    };
    let mut scheduler = Scheduler::new(
        &pm,
        ServeOpts { max_batch: batch, policy, prefix_cache: true, spec, ..Default::default() },
    );
    if let Some(d) = &draft {
        scheduler = scheduler.with_draft(d);
    }

    let mut rng = Pcg64::new(7);
    // all prompts share a prefix (half the requests one prefix, half
    // another), so the radix-trie prefix cache gets real hits
    let starts: Vec<usize> =
        (0..2).map(|_| rng.below(wiki.tokens.len() - prompt_len)).collect();
    for i in 0..n_requests {
        let base = starts[i % 2];
        let shared: Vec<i32> =
            wiki.tokens[base..base + shared_len].iter().map(|&t| t as i32).collect();
        let tail_at = rng.below(wiki.tokens.len() - prompt_len);
        let tail: Vec<i32> = wiki.tokens[tail_at..tail_at + (prompt_len - shared_len)]
            .iter()
            .map(|&t| t as i32)
            .collect();
        let prompt: Vec<i32> = shared.into_iter().chain(tail).collect();
        let sampler = override_sampler.unwrap_or(if i < n_requests / 2 {
            Sampler::Greedy
        } else {
            Sampler::TopK { k: 8, temperature: 0.8 }
        });
        let mut req = Request::new(i, prompt, gen_tokens, sampler);
        if i == 0 {
            // stream the first request's tokens as they are sampled; the
            // scheduler clamps max_new to the remaining context, so compute
            // the real stream length for the terminating newline
            let stream_len = gen_tokens.min(max_seq - prompt_len);
            req = req.with_sink(Box::new(FnSink(move |tok: i32, idx: usize| {
                use std::io::Write;
                if idx == 0 {
                    print!("stream[0]: ");
                }
                print!("{tok} ");
                if idx + 1 == stream_len {
                    println!();
                }
                let _ = std::io::stdout().flush();
            })));
        }
        scheduler.submit(req);
    }

    let (completions, stats) = scheduler.run();
    println!("{}", stats.summary());
    for c in completions.iter().take(2) {
        let tail = &c.prompt[c.prompt.len().saturating_sub(4)..];
        let head = &c.generated[..c.generated.len().min(8)];
        println!("sample {} ({}): ...{tail:?} -> {head:?}", c.id, c.finish.label());
    }
    println!("metrics: {}", scheduler.metrics().to_json().to_string());
    if let Some(path) = invarexplore::obs::trace_out_path() {
        let n = invarexplore::obs::chrome::dump(&path)?;
        println!("trace: {n} events -> {}", path.display());
        print!("{}", invarexplore::obs::prometheus::render(scheduler.metrics()));
    }
    Ok(())
}

//! Serve a quantized model directly from the packed (deployment) weight
//! format: the model stays bit-packed in RAM (`quant::packed`, 2-bit codes
//! + f16 group scales), the decoder forward runs on the packed codes
//! through the fused unpack→dequant→GEMV kernels, and each sequence decodes
//! incrementally against its own KV cache (`serve::Server`) — no dense f32
//! materialization of quantized linears and no full-context re-forward per
//! token.
//!
//! ```text
//! cargo run --release --example serve_quantized
//! ```

use invarexplore::baselines::{self, Method};
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::Session;
use invarexplore::quant::QuantScheme;
use invarexplore::serve::{Request, ServeOpts, Server};
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let model = "opt-small";
    let scheme = QuantScheme::new(2, 64);
    println!("== serving {model} quantized at {scheme} ==");

    // --- offline: quantize with AWQ and pack ------------------------------
    let w = session.weights(model)?;
    let pile = session.corpus("pile")?;
    let calib = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let prepared = baselines::prepare(Method::Awq, scheme, &w, &calib, None)?;
    let quantized = prepared.quantize_model(&prepared.fp, None);
    let pm = prepared.packed_model(&quantized);
    println!(
        "packed model: {:.2} MiB ({:.3} bits/param) for {} linear tensors, served as-is",
        pm.packed_bytes() as f64 / (1 << 20) as f64,
        pm.bits_per_param(),
        pm.n_packed()
    );

    // --- serve: batched generation with per-sequence KV caches ------------
    let batch = 8;
    let max_seq = pm.config().max_seq;
    let prompt_len = usize::min(32, max_seq / 2);
    let gen_tokens = 24;
    let wiki = session.corpus("wiki")?;
    anyhow::ensure!(
        wiki.tokens.len() > prompt_len,
        "wiki corpus too small for a {prompt_len}-token prompt"
    );

    // SERVE_SAMPLER overrides decoding for the whole batch (greedy,
    // temp:<t>, topk:<k>[:<t>]); default is half greedy / half top-k.
    let override_sampler = match std::env::var("SERVE_SAMPLER") {
        Ok(spec) => Some(Sampler::parse(&spec)?),
        Err(_) => None,
    };
    let mut server = Server::new(&pm, ServeOpts { max_batch: batch, seed: 0 });
    let mut rng = Pcg64::new(7);
    for i in 0..batch {
        // bounds-checked prompt sampling: any batch size works on any corpus
        let start = rng.below(wiki.tokens.len() - prompt_len);
        let prompt: Vec<i32> =
            wiki.tokens[start..start + prompt_len].iter().map(|&t| t as i32).collect();
        let sampler = override_sampler.unwrap_or(if i < batch / 2 {
            Sampler::Greedy
        } else {
            Sampler::TopK { k: 8, temperature: 0.8 }
        });
        server.submit(Request { id: i, prompt, max_new: gen_tokens, sampler });
    }

    let (completions, stats) = server.run();
    println!("{}", stats.summary());
    for c in completions.iter().take(2) {
        let tail = &c.prompt[c.prompt.len().saturating_sub(4)..];
        let head = &c.generated[..c.generated.len().min(8)];
        println!("sample {}: ...{tail:?} -> {head:?}", c.id);
    }
    Ok(())
}

//! Serve a quantized model: batched greedy generation with the packed
//! (deployment) weight format, reporting latency and throughput.
//!
//! Shows the deployment story end to end: the model is held in RAM in the
//! bit-packed form (`quant::packed`, 1-bit codes + f16 group scales),
//! unpacked tensor-by-tensor into the XLA engine, and served through the
//! AOT `head_logits` program with full-context re-forward per token (no KV
//! cache — honest about what this runtime implements).
//!
//! ```text
//! cargo run --release --example serve_quantized
//! ```

use std::time::Instant;

use invarexplore::baselines::{self, Method};
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::Session;
use invarexplore::quant::{PackedTensor, QuantScheme};
use invarexplore::runtime::Engine;
use invarexplore::util::rng::Pcg64;
use invarexplore::util::sampling::Sampler;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let model = "opt-small";
    let scheme = QuantScheme::new(2, 64);
    println!("== serving {model} quantized at {scheme} ==");

    // --- offline: quantize with AWQ and pack ------------------------------
    let w = session.weights(model)?;
    let pile = session.corpus("pile")?;
    let calib = CalibSet::from_corpus(&pile, 16, session.manifest.seq);
    let prepared = baselines::prepare(Method::Awq, scheme, &w, &calib, None)?;
    let quantized = prepared.quantize_model(&prepared.fp, None);

    let (packed, bytes) = prepared.pack_model(&quantized);
    let total: usize = packed.iter().map(|(_, t)| t.rows * t.cols).sum();
    println!(
        "packed model: {:.2} MiB ({:.3} bits/param) for {} linear tensors",
        bytes as f64 / (1 << 20) as f64,
        bytes as f64 * 8.0 / total as f64,
        packed.len()
    );

    // --- load: unpack packed codes into the engine ------------------------
    let mut engine = Engine::load(&session.manifest, model)?;
    engine.upload_weights(&prepared.fp)?; // embeddings/LN/biases stay FP
    let t0 = Instant::now();
    for (name, p) in &packed {
        let dense = PackedTensor::unpack(p);
        engine.update_tensor(name, &dense)?;
    }
    println!("unpack + upload: {:?}", t0.elapsed());

    // --- serve: batched greedy generation ----------------------------------
    let (b, t_max) = (engine.batch, engine.seq);
    let wiki = session.corpus("wiki")?;
    let prompt_len = 32;
    let gen_tokens = 24;
    let prompts: Vec<Vec<i32>> = (0..b)
        .map(|i| {
            wiki.tokens[i * 200..i * 200 + prompt_len]
                .iter()
                .map(|&t| t as i32)
                .collect()
        })
        .collect();

    // half the batch decodes greedily, half with top-k sampling
    let sampler_for = |i: usize| {
        if i < b / 2 {
            Sampler::Greedy
        } else {
            Sampler::TopK { k: 8, temperature: 0.8 }
        }
    };
    let mut rng = Pcg64::new(0);
    let mut seqs = prompts.clone();
    let t0 = Instant::now();
    let mut per_token = Vec::new();
    for _ in 0..gen_tokens {
        let t1 = Instant::now();
        // pad each sequence to the compiled T
        let cur_len = seqs[0].len();
        let tokens: Vec<Vec<i32>> = seqs
            .iter()
            .map(|s| {
                let mut padded = s.clone();
                padded.resize(t_max, 0);
                padded
            })
            .collect();
        let targets = vec![vec![0i32; t_max]; b];
        let mask = vec![vec![0f32; t_max]; b];
        let batch = engine.upload_batch(&tokens, &targets, &mask)?;
        let mut x = engine.embed(&batch)?;
        for l in 0..engine.n_layers() {
            x = engine.run_layer(l, &x)?;
        }
        let logits = engine.run_logits(&x)?; // [B*T, V]
        for (s, seq) in seqs.iter_mut().enumerate() {
            let row = logits.row(s * t_max + cur_len - 1);
            let next = sampler_for(s).sample(row, &mut rng) as i32;
            seq.push(next);
        }
        per_token.push(t1.elapsed());
    }
    let elapsed = t0.elapsed();
    let total_generated = b * gen_tokens;
    let mean_ms = per_token.iter().map(|d| d.as_secs_f64()).sum::<f64>() / per_token.len() as f64 * 1e3;
    println!(
        "generated {total_generated} tokens in {elapsed:?}: {:.1} tok/s, {mean_ms:.1} ms/decode-step (batch {b})",
        total_generated as f64 / elapsed.as_secs_f64()
    );
    for (i, s) in seqs.iter().take(2).enumerate() {
        println!("sample {i}: ...{:?} -> {:?}", &s[prompt_len - 4..prompt_len], &s[prompt_len..prompt_len + 8]);
    }
    Ok(())
}

//! Quickstart: the smallest complete InvarExplore run.
//!
//! Loads the smallest trained model, quantizes it to the ultra-low-bit
//! setting with plain RTN, runs a short activation-guided discrete search
//! (paper Algorithm 1), and prints perplexity before/after.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use invarexplore::baselines::Method;
use invarexplore::coordinator::{pipeline, PipelineOpts, Session};
use invarexplore::quant::QuantScheme;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;

    // ultra-low-bit setting: 1-bit, group 64 (see DESIGN.md §1 — our small
    // models' difficulty curve sits one bit below the paper's)
    let mut opts = PipelineOpts::new("opt-tiny", Method::Rtn, QuantScheme::new(1, 64));
    opts.steps = 150;
    opts.calib_seqs = 16;
    opts.eval_seqs = 32;

    println!("== InvarExplore quickstart: {} + {} ==", opts.model, opts.scheme);
    let fp = pipeline::eval_fp(&session, &opts.model, &opts)?;
    println!("FP32 model      : wiki ppl {:8.2}   c4 ppl {:8.2}", fp.ppl_wiki, fp.ppl_c4);

    let report = pipeline::run_pipeline(&session, &opts)?;
    println!(
        "RTN quantized   : wiki ppl {:8.2}   c4 ppl {:8.2}",
        report.base.ppl_wiki, report.base.ppl_c4
    );
    let s = report.searched.expect("search ran");
    let st = report.state.expect("state");
    println!(
        "+InvarExplore   : wiki ppl {:8.2}   c4 ppl {:8.2}   ({} steps, {:.0}% accepted)",
        s.ppl_wiki,
        s.ppl_c4,
        st.step,
        100.0 * st.accept_rate()
    );
    println!(
        "recovered {:.1}% of the RTN wiki-ppl damage",
        100.0 * (report.base.ppl_wiki - s.ppl_wiki) / (report.base.ppl_wiki - fp.ppl_wiki).max(1e-9)
    );
    Ok(())
}

//! End-to-end driver (the EXPERIMENTS.md headline run): the full system on
//! a real small workload, proving all layers compose.
//!
//! Pipeline on the largest trained model (`opt-base`, ~5.6M params, trained
//! at build time on the synthetic corpus):
//!
//! 1. FP32 baseline eval — perplexity on two held-out corpora + six-task
//!    few-shot reasoning (through the AOT XLA programs);
//! 2. AWQ 1-bit quantization (activation-aware scaling + clipping, built
//!    from scratch) + packed-memory accounting;
//! 3. InvarExplore activation-guided discrete search (paper Algorithm 1) —
//!    the L3 Rust coordinator driving per-proposal Pallas/XLA evaluation;
//! 4. post-search eval + a search telemetry summary (Figure-1 style).
//!
//! ```text
//! make artifacts && cargo run --release --example quantize_and_search
//! INVAREXPLORE_STEPS=2000 cargo run --release --example quantize_and_search   # longer
//! ```

use invarexplore::baselines::{self, Method};
use invarexplore::calib::CalibSet;
use invarexplore::coordinator::{pipeline, PipelineOpts, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::util::bench::step_budget;
use invarexplore::util::plot;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let model = "opt-base";
    let scheme = QuantScheme::new(1, 64);
    let steps = step_budget(400);

    let mut opts = PipelineOpts::new(model, Method::Awq, scheme);
    opts.steps = steps;
    opts.reasoning_n = 60;
    opts.eval_seqs = 64;

    println!("== InvarExplore end-to-end: {model} + AWQ @ {scheme}, {steps} search steps ==\n");

    // 1. FP32 reference
    let fp = pipeline::eval_fp(&session, model, &opts)?;
    let fp_acc = fp.reasoning.as_ref().map(|(_, a)| *a).unwrap_or(0.0);
    println!("[1] FP32       wiki {:7.2}  c4 {:7.2}  reasoning {:5.2}", fp.ppl_wiki, fp.ppl_c4, fp_acc);

    // 2. memory accounting of the packed deployment form
    let w = session.weights(model)?;
    let pile = session.corpus("pile")?;
    let calib = CalibSet::from_corpus(&pile, opts.calib_seqs, session.manifest.seq);
    let prepared = baselines::prepare(Method::Awq, scheme, &w, &calib, None)?;
    let (packed, bytes) = prepared.pack_model(&prepared.fp);
    let total: usize = packed.iter().map(|(_, t)| t.rows * t.cols).sum();
    println!(
        "[2] packed     {:.2} MiB vs {:.2} MiB FP16 ({:.1}% saving, {:.3} bits/param)",
        bytes as f64 / (1 << 20) as f64,
        (total * 2) as f64 / (1 << 20) as f64,
        100.0 * (1.0 - bytes as f64 / (total * 2) as f64),
        bytes as f64 * 8.0 / total as f64
    );

    // 3 + 4. quantize, search, re-evaluate
    let report = pipeline::run_pipeline(&session, &opts)?;
    let base_acc = report.base.reasoning.as_ref().map(|(_, a)| *a).unwrap_or(0.0);
    println!(
        "[3] AWQ        wiki {:7.2}  c4 {:7.2}  reasoning {:5.2}",
        report.base.ppl_wiki, report.base.ppl_c4, base_acc
    );
    let s = report.searched.expect("searched");
    let st = report.state.expect("state");
    let s_acc = s.reasoning.as_ref().map(|(_, a)| *a).unwrap_or(0.0);
    println!(
        "[4] +InvarExpl wiki {:7.2}  c4 {:7.2}  reasoning {:5.2}   (accept {:.0}%)",
        s.ppl_wiki,
        s.ppl_c4,
        s_acc,
        100.0 * st.accept_rate()
    );

    // telemetry summary (Figure-1 style loss curve)
    let series: Vec<(f64, f64)> = st
        .telemetry
        .iter()
        .step_by((st.telemetry.len() / 64).max(1))
        .map(|r| (r.step as f64, r.loss_total))
        .collect();
    println!("\n{}", plot::render("search objective (CE + α·MSE)", &[("loss", &series)], 64, 12));

    // headline summary
    println!("== headline ==");
    println!(
        "wiki ppl: FP {:.2} → AWQ {:.2} → +InvarExplore {:.2}  ({:+.1}% vs AWQ)",
        fp.ppl_wiki,
        report.base.ppl_wiki,
        s.ppl_wiki,
        100.0 * (s.ppl_wiki - report.base.ppl_wiki) / report.base.ppl_wiki
    );
    println!(
        "reasoning: FP {:.2} → AWQ {:.2} → +InvarExplore {:.2}",
        fp_acc, base_acc, s_acc
    );

    // persist run for EXPERIMENTS.md
    let dir = invarexplore::coordinator::tables::results_dir();
    st.telemetry_csv(&dir.join("e2e_telemetry.csv"))?;
    st.save(&dir.join("e2e_state.json"))?;
    println!("\ntelemetry/state written under {}", dir.display());
    Ok(())
}

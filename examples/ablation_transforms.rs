//! Transform ablation (paper Table 2, interactive version): run the search
//! with each invariance family alone — permutation / scaling / rotation —
//! and combined, on the same base quantized model, and compare.
//!
//! Demonstrates the paper's §4.2 findings at this scale: every family helps
//! alone, permutation (non-differentiable, unreachable by gradient methods)
//! is a strong contributor, and the combination is the best.
//!
//! ```text
//! cargo run --release --example ablation_transforms
//! ```

use invarexplore::baselines::Method;
use invarexplore::coordinator::{PipelineOpts, SearchRun, Session};
use invarexplore::quant::QuantScheme;
use invarexplore::transform::TransformKinds;
use invarexplore::util::bench::step_budget;

fn main() -> anyhow::Result<()> {
    let session = Session::load_default()?;
    let model = "opt-small";
    let steps = step_budget(250);
    println!("== transform ablation: AWQ + {model} @ 1-bit g64, {steps} steps each ==\n");

    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for (label, kinds) in [
        ("baseline (no search)", ""),
        ("permutation only", "p"),
        ("scaling only", "s"),
        ("rotation only", "r"),
        ("P + S + R", "psr"),
    ] {
        let mut opts = PipelineOpts::new(model, Method::Awq, QuantScheme::new(1, 64));
        opts.calib_seqs = 16;
        opts.eval_seqs = 48;
        let mut run = SearchRun::build(&session, &opts)?;
        run.init()?;
        let loss0 = run.state.best.total(run.state.alpha);
        if !kinds.is_empty() {
            run.cfg.kinds = TransformKinds::parse(kinds)?;
            run.steps(steps)?;
        }
        let loss1 = run.state.best.total(run.state.alpha);
        let ppl = run.test_ppl(&session, "wiki", 48)?;
        println!(
            "{label:22}  calib loss {loss0:.3} -> {loss1:.3}   wiki ppl {ppl:8.2}   accept {:4.0}%",
            100.0 * run.state.accept_rate()
        );
        rows.push((label.to_string(), loss0, loss1, ppl));
    }

    // sanity summary: combined should be the best searched variant
    let base_ppl = rows[0].3;
    let combined = rows.last().unwrap().3;
    println!("\nbaseline wiki ppl {base_ppl:.2} -> combined P+S+R {combined:.2} ({:+.1}%)",
        100.0 * (combined - base_ppl) / base_ppl);
    Ok(())
}

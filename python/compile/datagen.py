"""Synthetic data generation for the InvarExplore reproduction.

The paper evaluates on WikiText-2 / C4 (perplexity), calibrates on the Pile,
and tests six reasoning benchmarks through lm-eval-harness.  None of those
corpora are reachable in this offline sandbox, so this module builds the
closest synthetic equivalent (see DESIGN.md §1):

* one seeded stochastic grammar over a small word-id vocabulary, with several
  topic "domains"; the three corpora (``pile``/``wiki``/``c4``) are different
  domain *mixtures*, preserving the calibrate-on-A / evaluate-on-B
  distribution shift of the paper;
* six few-shot multiple-choice task generators whose answers are
  statistically learnable from the corpus patterns, exercising the same
  masked option-log-likelihood eval path as lm-eval-harness.

Everything is deterministic given a seed.  Token ids are word ids directly
(no BPE): vocab layout is

  0          <pad>
  1          <bos>
  2          <eos>
  3..V-1     words, organised into topic clusters + function words + digits

Output formats (read by rust/src/io/):
  *.tok   little-endian u32 token stream with a 16-byte header
          (magic "IVTK", u32 version, u32 vocab, u32 count)
  *.json  task files: list of {"ctx": [...], "options": [[...], ...],
          "answer": int}
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field

import numpy as np

MAGIC = b"IVTK"
VERSION = 1

PAD, BOS, EOS = 0, 1, 2


# ---------------------------------------------------------------------------
# Vocabulary layout
# ---------------------------------------------------------------------------

@dataclass
class VocabSpec:
    """Structured layout of the synthetic vocabulary.

    The grammar needs distinguishable word classes; everything is an id
    range.  ``n_topics`` topic clusters each own ``topic_size`` nouns; a
    shared pool of verbs/adjectives/function words/digits completes the
    vocabulary.
    """

    vocab: int
    n_topics: int = 8
    # Fractions of the non-special id space allotted to each class.
    frac_nouns: float = 0.5
    frac_verbs: float = 0.2
    frac_adjs: float = 0.15
    frac_func: float = 0.1

    def __post_init__(self) -> None:
        usable = self.vocab - 3 - 10  # specials + ten digit words
        self.n_nouns = max(self.n_topics * 4, int(usable * self.frac_nouns))
        self.n_nouns -= self.n_nouns % self.n_topics
        self.n_verbs = max(8, int(usable * self.frac_verbs))
        self.n_adjs = max(8, int(usable * self.frac_adjs))
        self.n_func = max(6, int(usable * self.frac_func))
        base = 3
        self.noun0 = base
        self.verb0 = self.noun0 + self.n_nouns
        self.adj0 = self.verb0 + self.n_verbs
        self.func0 = self.adj0 + self.n_adjs
        self.digit0 = self.func0 + self.n_func
        assert self.digit0 + 10 <= self.vocab, "vocab too small for layout"
        self.topic_size = self.n_nouns // self.n_topics

    def topic_nouns(self, t: int) -> np.ndarray:
        lo = self.noun0 + t * self.topic_size
        return np.arange(lo, lo + self.topic_size, dtype=np.uint32)

    def digits(self) -> np.ndarray:
        return np.arange(self.digit0, self.digit0 + 10, dtype=np.uint32)


# ---------------------------------------------------------------------------
# Sentence grammar
# ---------------------------------------------------------------------------

class Grammar:
    """Seeded stochastic grammar emitting English-like token sequences.

    Key *learnable regularities* (the reasoning tasks below probe exactly
    these, so a trained model scores above chance and quantization damage is
    measurable):

    1. topic coherence: a sentence's nouns come from one topic cluster;
    2. agreement: each topic has a preferred verb subset ("agreement" rule:
       verb id ≡ topic (mod n_topics) with prob 0.9);
    3. copy/recall: a sentence sometimes repeats its subject noun at the end;
    4. ordering: digit words appear in ascending runs with prob 0.9;
    5. comparatives: the pattern ``func[0] d_i func[1] d_j`` holds i<j with
       prob 0.9 ("X less-than Y");
    6. boolean: ``func[2] noun verb func[3]`` ("does noun verb? yes") iff the
       agreement rule holds, else ``func[4]`` ("no").
    """

    def __init__(self, spec: VocabSpec, seed: int):
        self.spec = spec
        self.rng = np.random.default_rng(seed)

    # -- helpers ------------------------------------------------------------

    def _topic_verb(self, topic: int, agree: bool) -> int:
        sp = self.spec
        n_groups = sp.n_verbs // sp.n_topics
        if n_groups == 0:
            return int(sp.verb0 + topic % sp.n_verbs)
        if agree:
            g = self.rng.integers(n_groups)
            return int(sp.verb0 + topic + g * sp.n_topics)
        # disagreeing verb: wrong residue class
        while True:
            v = int(self.rng.integers(sp.n_verbs))
            if v % sp.n_topics != topic:
                return sp.verb0 + v

    def _noun(self, topic: int) -> int:
        return int(self.rng.choice(self.spec.topic_nouns(topic)))

    def _adj(self) -> int:
        return int(self.spec.adj0 + self.rng.integers(self.spec.n_adjs))

    # -- sentence forms -----------------------------------------------------

    def sent_svo(self, topic: int) -> list[int]:
        """noun [adj] verb noun — with topical agreement."""
        s = [self._noun(topic)]
        if self.rng.random() < 0.4:
            s.append(self._adj())
        agree = self.rng.random() < 0.9
        s.append(self._topic_verb(topic, agree))
        s.append(self._noun(topic))
        if self.rng.random() < 0.35:  # copy/recall regularity
            s.append(s[0])
        return s

    def sent_digits(self) -> list[int]:
        """Ascending digit run (prob 0.9) of length 3-6."""
        d = self.spec.digits()
        n = int(self.rng.integers(3, 7))
        if self.rng.random() < 0.9:
            start = int(self.rng.integers(0, 10 - n + 1))
            return list(map(int, d[start : start + n]))
        return list(map(int, self.rng.choice(d, size=n)))

    def sent_compare(self) -> list[int]:
        """func[0] d_i func[1] d_j with i<j (prob 0.9)."""
        sp = self.spec
        d = sp.digits()
        i, j = sorted(self.rng.choice(10, size=2, replace=False))
        if self.rng.random() >= 0.9:
            i, j = j, i
        return [sp.func0, int(d[i]), sp.func0 + 1, int(d[j])]

    def sent_bool(self, topic: int) -> list[int]:
        """func[2] noun verb {func[3]=yes | func[4]=no} — truth = agreement."""
        sp = self.spec
        agree = self.rng.random() < 0.5
        n = self._noun(topic)
        v = self._topic_verb(topic, agree)
        ans = sp.func0 + 3 if agree else sp.func0 + 4
        out = [sp.func0 + 2, n, v, ans]
        # 10% label noise keeps the task non-degenerate
        if self.rng.random() < 0.1:
            out[-1] = sp.func0 + 3 if not agree else sp.func0 + 4
        return out

    # -- documents ----------------------------------------------------------

    #: per-domain sentence-form mixture: (svo, digits, compare, bool)
    DOMAIN_MIX = {
        "narrative": (0.85, 0.05, 0.05, 0.05),
        "technical": (0.45, 0.30, 0.15, 0.10),
        "dialogue": (0.55, 0.05, 0.10, 0.30),
    }

    def document(self, domain: str, n_sents: int) -> list[int]:
        mix = np.asarray(self.DOMAIN_MIX[domain])
        topic = int(self.rng.integers(self.spec.n_topics))
        toks: list[int] = [BOS]
        for _ in range(n_sents):
            if self.rng.random() < 0.15:  # topic drift
                topic = int(self.rng.integers(self.spec.n_topics))
            k = int(self.rng.choice(4, p=mix))
            if k == 0:
                toks += self.sent_svo(topic)
            elif k == 1:
                toks += self.sent_digits()
            elif k == 2:
                toks += self.sent_compare()
            else:
                toks += self.sent_bool(topic)
        toks.append(EOS)
        return toks

    def corpus(self, mixture: dict[str, float], n_tokens: int) -> np.ndarray:
        """Concatenate documents until ``n_tokens`` tokens are emitted."""
        domains = list(mixture)
        probs = np.asarray([mixture[d] for d in domains])
        probs = probs / probs.sum()
        out: list[int] = []
        while len(out) < n_tokens:
            d = domains[int(self.rng.choice(len(domains), p=probs))]
            out += self.document(d, n_sents=int(self.rng.integers(6, 14)))
        return np.asarray(out[:n_tokens], dtype=np.uint32)


#: corpus name -> domain mixture.  ``pile`` (calibration) is the broadest;
#: ``wiki``/``c4`` shift the mixture like the paper's eval-set shift.
CORPUS_MIXTURES = {
    "pile": {"narrative": 0.4, "technical": 0.35, "dialogue": 0.25},
    "wiki": {"narrative": 0.6, "technical": 0.3, "dialogue": 0.1},
    "c4": {"narrative": 0.45, "technical": 0.2, "dialogue": 0.35},
}


# ---------------------------------------------------------------------------
# Reasoning tasks
# ---------------------------------------------------------------------------

@dataclass
class TaskExample:
    ctx: list[int]
    options: list[list[int]]
    answer: int

    def to_dict(self) -> dict:
        return {"ctx": self.ctx, "options": self.options, "answer": self.answer}


class TaskGen:
    """Six synthetic multiple-choice tasks (paper: ARC-E/C, BoolQ, HellaSwag,
    PIQA, WinoGrande).  Each probes one grammar regularity; options are
    token suffixes scored by masked log-likelihood (see rust eval harness).
    """

    TASKS = ("assoc", "agree", "copy", "order", "compare", "bool")

    def __init__(self, spec: VocabSpec, seed: int):
        self.spec = spec
        self.g = Grammar(spec, seed)
        self.rng = self.g.rng

    def gen(self, task: str, n: int) -> list[TaskExample]:
        fn = getattr(self, f"task_{task}")
        return [fn() for _ in range(n)]

    def _distract_topics(self, topic: int, k: int) -> list[int]:
        others = [t for t in range(self.spec.n_topics) if t != topic]
        picks = self.rng.choice(len(others), size=k, replace=False)
        return [others[int(i)] for i in picks]

    def task_assoc(self) -> TaskExample:
        """Topic association (~HellaSwag): context sentence from topic t;
        which continuation noun belongs to t?"""
        t = int(self.rng.integers(self.spec.n_topics))
        ctx = [BOS] + self.g.sent_svo(t) + self.g.sent_svo(t)
        correct = [self.g._noun(t)]
        opts = [[self.g._noun(d)] for d in self._distract_topics(t, 3)]
        ans = int(self.rng.integers(4))
        opts.insert(ans, correct)
        return TaskExample(ctx, opts, ans)

    def task_agree(self) -> TaskExample:
        """Agreement (~WinoGrande): which verb agrees with the subject?"""
        t = int(self.rng.integers(self.spec.n_topics))
        ctx = [BOS] + self.g.sent_svo(t)[:-1] + [self.g._noun(t)]
        good = [self.g._topic_verb(t, True)]
        bad = [self.g._topic_verb(t, False)]
        ans = int(self.rng.integers(2))
        opts = [bad, good] if ans == 1 else [good, bad]
        return TaskExample(ctx + [self.g._noun(t)], opts, ans)

    def task_copy(self) -> TaskExample:
        """Recall (~ARC-E): which noun was the subject of the sentence?"""
        t = int(self.rng.integers(self.spec.n_topics))
        sent = self.g.sent_svo(t)
        subj = sent[0]
        ctx = [BOS] + sent[:-1] if sent[-1] == sent[0] else [BOS] + sent
        correct = [subj]
        # distractors: other nouns from the *same* topic (hard, ~ARC-C-ish)
        opts = []
        while len(opts) < 3:
            n = self.g._noun(t)
            if n != subj and [n] not in opts:
                opts.append([n])
        ans = int(self.rng.integers(4))
        opts.insert(ans, correct)
        return TaskExample(ctx, opts, ans)

    def task_order(self) -> TaskExample:
        """Sequence completion (~PIQA): ascending digit run; next digit?"""
        d = self.spec.digits()
        start = int(self.rng.integers(0, 6))
        ln = int(self.rng.integers(3, min(5, 10 - start - 1) + 1))
        ctx = [BOS] + list(map(int, d[start : start + ln]))
        nxt = int(d[start + ln])
        wrong = int(self.rng.choice([x for x in d if x != nxt]))
        ans = int(self.rng.integers(2))
        opts = [[wrong], [nxt]] if ans == 1 else [[nxt], [wrong]]
        return TaskExample(ctx, opts, ans)

    def task_compare(self) -> TaskExample:
        """Comparatives (~ARC-C): func0 d_i func1 ? — which digit > d_i?"""
        sp = self.spec
        d = sp.digits()
        i = int(self.rng.integers(0, 9))
        j_hi = int(self.rng.integers(i + 1, 10))
        j_lo = int(self.rng.integers(0, i + 1))
        ctx = [BOS, sp.func0, int(d[i]), sp.func0 + 1]
        ans = int(self.rng.integers(2))
        opts = (
            [[int(d[j_lo])], [int(d[j_hi])]]
            if ans == 1
            else [[int(d[j_hi])], [int(d[j_lo])]]
        )
        return TaskExample(ctx, opts, ans)

    def task_bool(self) -> TaskExample:
        """Yes/no (~BoolQ): func2 noun verb -> yes iff agreement holds."""
        sp = self.spec
        t = int(self.rng.integers(sp.n_topics))
        agree = self.rng.random() < 0.5
        ctx = [BOS, sp.func0 + 2, self.g._noun(t), self.g._topic_verb(t, agree)]
        yes, no = [sp.func0 + 3], [sp.func0 + 4]
        answer_tok = yes if agree else no
        other = no if agree else yes
        ans = int(self.rng.integers(2))
        opts = [other, answer_tok] if ans == 1 else [answer_tok, other]
        return TaskExample(ctx, opts, ans)


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

def write_tokens(path: str, tokens: np.ndarray, vocab: int) -> None:
    tokens = np.asarray(tokens, dtype="<u4")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<III", VERSION, vocab, len(tokens)))
        f.write(tokens.tobytes())


def read_tokens(path: str) -> tuple[np.ndarray, int]:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == MAGIC, f"bad magic {magic!r}"
        version, vocab, count = struct.unpack("<III", f.read(12))
        assert version == VERSION
        data = np.frombuffer(f.read(4 * count), dtype="<u4")
    return data, vocab


def write_tasks(path: str, examples: list[TaskExample]) -> None:
    with open(path, "w") as f:
        json.dump([e.to_dict() for e in examples], f)


# ---------------------------------------------------------------------------
# Top-level driver
# ---------------------------------------------------------------------------

@dataclass
class DataPlan:
    vocab: int
    seed: int
    train_tokens: int
    eval_tokens: int
    calib_tokens: int
    task_examples: int = 200


def generate_all(outdir: str, plan: DataPlan) -> dict:
    """Generate corpora + tasks for one vocab size; returns a manifest dict."""
    import os

    os.makedirs(outdir, exist_ok=True)
    spec = VocabSpec(plan.vocab)
    manifest: dict = {"vocab": plan.vocab, "seed": plan.seed, "corpora": {}, "tasks": {}}

    # training corpus = pile mixture (models are trained on the broad mix)
    # Manifest paths are *filenames* relative to the data directory; aot.py
    # re-roots them relative to the artifacts dir for the Rust loader.
    g = Grammar(spec, plan.seed)
    train = g.corpus(CORPUS_MIXTURES["pile"], plan.train_tokens)
    write_tokens(os.path.join(outdir, "train.tok"), train, plan.vocab)
    manifest["corpora"]["train"] = {"path": "train.tok", "tokens": int(len(train))}

    for name, offs in (("pile", 1), ("wiki", 2), ("c4", 3)):
        gg = Grammar(spec, plan.seed + 1000 * offs)
        n = plan.calib_tokens if name == "pile" else plan.eval_tokens
        toks = gg.corpus(CORPUS_MIXTURES[name], n)
        write_tokens(os.path.join(outdir, f"{name}.tok"), toks, plan.vocab)
        manifest["corpora"][name] = {"path": f"{name}.tok", "tokens": int(len(toks))}

    tg = TaskGen(spec, plan.seed + 7777)
    for task in TaskGen.TASKS:
        ex = tg.gen(task, plan.task_examples)
        write_tasks(os.path.join(outdir, f"task_{task}.json"), ex)
        manifest["tasks"][task] = {"path": f"task_{task}.json", "n": len(ex)}

    with open(os.path.join(outdir, "data_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/data")
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--train-tokens", type=int, default=2_000_000)
    ap.add_argument("--eval-tokens", type=int, default=65_536)
    ap.add_argument("--calib-tokens", type=int, default=32_768)
    a = ap.parse_args()
    m = generate_all(
        a.out,
        DataPlan(a.vocab, a.seed, a.train_tokens, a.eval_tokens, a.calib_tokens),
    )
    print(json.dumps(m, indent=2))

"""`.iwt` — the InvarExplore weight-tensor container.

A safetensors-like single-file format shared between the Python build path
(writer) and the Rust runtime (reader — rust/src/io/iwt.rs):

    bytes 0..4    magic  b"IVWT"
    bytes 4..8    u32 LE version (1)
    bytes 8..16   u64 LE header length H
    bytes 16..16+H  UTF-8 JSON header:
        {"tensors": {name: {"dtype": "f32", "shape": [..],
                            "offset": int, "nbytes": int}, ...},
         "meta": {...arbitrary string map...}}
    then raw little-endian tensor data; offsets are relative to the start of
    the data section and 64-byte aligned.
"""

from __future__ import annotations

import json
import struct

import numpy as np

MAGIC = b"IVWT"
VERSION = 1
ALIGN = 64


def write_iwt(path: str, tensors: dict[str, np.ndarray], meta: dict[str, str] | None = None) -> None:
    entries = {}
    offset = 0
    blobs = []
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr, dtype="<f4")
        nbytes = arr.nbytes
        entries[name] = {
            "dtype": "f32",
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": nbytes,
        }
        blobs.append(arr.tobytes())
        offset += nbytes
        pad = (-offset) % ALIGN
        if pad:
            blobs.append(b"\x00" * pad)
            offset += pad
    header = json.dumps({"tensors": entries, "meta": meta or {}}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", VERSION))
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def read_iwt(path: str) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad .iwt magic"
        (version,) = struct.unpack("<I", f.read(4))
        assert version == VERSION
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen).decode())
        data = f.read()
    out = {}
    for name, e in header["tensors"].items():
        assert e["dtype"] == "f32"
        raw = data[e["offset"] : e["offset"] + e["nbytes"]]
        out[name] = np.frombuffer(raw, dtype="<f4").reshape(e["shape"]).copy()
    return out, header.get("meta", {})

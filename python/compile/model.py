"""Layer-2 JAX model: OPT-style decoder-only transformer.

Mirrors the OPT architecture properties that the paper's invariance algebra
relies on (DESIGN.md §1): pre-LN decoder blocks, learned positional
embeddings, a **ReLU** feed-forward network ``W_down · relu(W_up·x + b_up) +
b_down`` (so the scaling invariance of Eqns. 12-15 is *exact*), and a tied
LM head.

All linear weights follow the row-major ``[out, in]`` convention shared with
the Rust side (``y = x @ W.T + b``); quantization groups run along the input
dimension.

The quantized variant (`forward_quant`) applies the Layer-1 Pallas
fake-quant kernel to every attention/FFN linear weight inside the graph, so
the whole thing lowers into a single HLO program that the Rust runtime
executes on the search hot path for end-to-end validation.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels.quant_kernel import fake_quant

LN_EPS = 1e-5


@dataclass(frozen=True)
class OptConfig:
    """Model hyper-parameters (kept in sync with rust model::OptConfig)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ffn: int
    max_seq: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)


#: The three build-time model sizes (paper: OPT 1.3B / 2.7B-6.7B / 13B trend
#: is reproduced as a 3-point sweep — see DESIGN.md substitution log).
MODEL_SIZES = {
    "opt-tiny": OptConfig("opt-tiny", vocab=2048, d_model=128, n_layers=2, n_heads=4, d_ffn=512, max_seq=128),
    "opt-small": OptConfig("opt-small", vocab=2048, d_model=192, n_layers=3, n_heads=6, d_ffn=768, max_seq=128),
    "opt-base": OptConfig("opt-base", vocab=2048, d_model=320, n_layers=4, n_heads=8, d_ffn=1280, max_seq=128),
}

#: Per-layer parameter names, in the canonical flattening order used by the
#: HLO programs and the .iwt weight file (keep in sync with rust io/model).
LAYER_PARAM_NAMES = (
    "ln1.w", "ln1.b",
    "q.w", "q.b", "k.w", "k.b", "v.w", "v.b", "o.w", "o.b",
    "ln2.w", "ln2.b",
    "up.w", "up.b", "down.w", "down.b",
)
#: Names of the quantizable (linear) tensors within a layer.
LAYER_QUANT_NAMES = ("q.w", "k.w", "v.w", "o.w", "up.w", "down.w")


def param_names(cfg: OptConfig) -> list[str]:
    """Canonical flat parameter-name order for a model."""
    names = ["emb", "pos"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.{n}" for n in LAYER_PARAM_NAMES]
    names += ["lnf.w", "lnf.b"]
    return names


def init_params(cfg: OptConfig, key) -> dict[str, jnp.ndarray]:
    """Scaled-normal init (GPT-2 style residual scaling)."""
    ks = iter(jax.random.split(key, 4 + 16 * cfg.n_layers))
    d, f = cfg.d_model, cfg.d_ffn
    p: dict[str, jnp.ndarray] = {}
    p["emb"] = jax.random.normal(next(ks), (cfg.vocab, d)) * 0.02
    p["pos"] = jax.random.normal(next(ks), (cfg.max_seq, d)) * 0.01
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        p[pre + "ln1.w"] = jnp.ones(d)
        p[pre + "ln1.b"] = jnp.zeros(d)
        for nm in ("q", "k", "v"):
            p[pre + nm + ".w"] = jax.random.normal(next(ks), (d, d)) * (0.02)
            p[pre + nm + ".b"] = jnp.zeros(d)
        p[pre + "o.w"] = jax.random.normal(next(ks), (d, d)) * (0.02 * resid_scale)
        p[pre + "o.b"] = jnp.zeros(d)
        p[pre + "ln2.w"] = jnp.ones(d)
        p[pre + "ln2.b"] = jnp.zeros(d)
        p[pre + "up.w"] = jax.random.normal(next(ks), (f, d)) * 0.02
        p[pre + "up.b"] = jnp.zeros(f)
        p[pre + "down.w"] = jax.random.normal(next(ks), (d, f)) * (0.02 * resid_scale)
        p[pre + "down.b"] = jnp.zeros(d)
    p["lnf.w"] = jnp.ones(d)
    p["lnf.b"] = jnp.zeros(d)
    return {k: v.astype(jnp.float32) for k, v in p.items()}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def layer_norm(x, w, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * w + b


def linear(x, w, b):
    """x [..., in] @ w[out, in].T + b[out]."""
    return x @ w.T + b


def attention(x, p, pre: str, cfg: OptConfig):
    """Causal multi-head self-attention (pre-LN block half)."""
    B, T, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    h = layer_norm(x, p[pre + "ln1.w"], p[pre + "ln1.b"])
    q = linear(h, p[pre + "q.w"], p[pre + "q.b"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = linear(h, p[pre + "k.w"], p[pre + "k.b"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    v = linear(h, p[pre + "v.w"], p[pre + "v.b"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return x + linear(out, p[pre + "o.w"], p[pre + "o.b"])


def ffn(x, p, pre: str):
    """The ReLU FFN block — the invariance site (Eqn. 7)."""
    h = layer_norm(x, p[pre + "ln2.w"], p[pre + "ln2.b"])
    u = jax.nn.relu(linear(h, p[pre + "up.w"], p[pre + "up.b"]))
    return x + linear(u, p[pre + "down.w"], p[pre + "down.b"])


def block(x, p, i: int, cfg: OptConfig):
    pre = f"l{i}."
    return ffn(attention(x, p, pre, cfg), p, pre)


def embed(tokens, p, cfg: OptConfig):
    B, T = tokens.shape
    return p["emb"][tokens] + p["pos"][:T][None]


def lm_logits(x, p):
    """Final LN + tied LM head."""
    h = layer_norm(x, p["lnf.w"], p["lnf.b"])
    return h @ p["emb"].T


def heads(x, targets, mask, p):
    """CE (mean over mask) + per-sequence masked log-prob."""
    logits = lm_logits(x, p)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(tgt_logp * mask).sum() / denom
    seq_logprob = (tgt_logp * mask).sum(axis=-1)
    return ce, seq_logprob


def forward_fp(tokens, targets, mask, p, cfg: OptConfig):
    """FP forward: (ce, seq_logprob [B], hidden stack [L, B, T, D]).

    The hidden stack is the post-block residual stream of every layer — the
    H (resp. H0) of the activation-matching loss, Eqn. 23.
    """
    x = embed(tokens, p, cfg)
    acts = []
    for i in range(cfg.n_layers):
        x = block(x, p, i, cfg)
        acts.append(x)
    ce, seq_logprob = heads(x, targets, mask, p)
    return ce, seq_logprob, jnp.stack(acts)


def quantize_params(p, cfg: OptConfig, bits: int, group: int):
    """Apply the L1 Pallas fake-quant kernel to every linear weight."""
    q = dict(p)
    for i in range(cfg.n_layers):
        for nm in LAYER_QUANT_NAMES:
            k = f"l{i}.{nm}"
            q[k] = fake_quant(p[k], bits, group)
    return q


def forward_quant(tokens, targets, mask, h0, p, cfg: OptConfig, bits: int, group: int):
    """Quantized forward with in-graph Pallas fake-quant.

    Takes the FP activation stack ``h0`` as an input and emits the search
    objective pieces: (ce, seq_logprob, act_mse) — Eqn. 23's two terms.
    """
    qp = quantize_params(p, cfg, bits, group)
    x = embed(tokens, qp, cfg)
    mse = 0.0
    for i in range(cfg.n_layers):
        x = block(x, qp, i, cfg)
        mse = mse + jnp.mean((x - h0[i]) ** 2)
    ce, seq_logprob = heads(x, targets, mask, qp)
    return ce, seq_logprob, mse / cfg.n_layers


# --- Per-stage functions for the layer-pipelined runtime -------------------

def stage_embed(tokens, emb, pos):
    T = tokens.shape[1]
    return emb[tokens] + pos[:T][None]


def stage_layer(x, layer_params: dict, cfg: OptConfig):
    """One decoder block given its 16 tensors (names without the l{i} prefix)."""
    p = {f"l0.{k}": v for k, v in layer_params.items()}
    return block(x, p, 0, cfg)


def stage_head(x, targets, mask, emb, lnf_w, lnf_b):
    p = {"emb": emb, "lnf.w": lnf_w, "lnf.b": lnf_b}
    return heads(x, targets, mask, p)


def stage_head_logits(x, emb, lnf_w, lnf_b):
    p = {"emb": emb, "lnf.w": lnf_w, "lnf.b": lnf_b}
    return lm_logits(x, p)

"""Layer-1 Pallas kernel: groupwise asymmetric integer fake-quantization.

This is the compute hot-spot of InvarExplore: every hill-climbing proposal
re-quantizes the mutated FFN block, and the in-graph quantized forward
(`forward_q*` programs) fake-quantizes every linear weight on every call.

TPU mapping (DESIGN.md §2): the grid is ``(rows / BLOCK_ROWS, cols /
group)`` so each program instance owns ``BLOCK_ROWS`` complete quantization
groups.  The max/min reduction never crosses a block boundary, the block
(``BLOCK_ROWS × group × 4`` bytes ≤ 4 KiB) lives comfortably in VMEM, and
Pallas's automatic double-buffering streams HBM at full bandwidth — the
kernel is memory-bound by construction (arithmetic intensity ≈ 0.75 flop/B).

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO ops
that the Rust runtime's CPU client runs directly.  Real-TPU performance is
estimated from the VMEM/bandwidth model in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Row-tile size.  All model dims in this repo are multiples of 8; 8 rows ×
#: group ≤ 64 cols × 4 B = 2 KiB per input block.
BLOCK_ROWS = 8


def _fake_quant_block(w_ref, o_ref, *, qmax: float):
    """One (BLOCK_ROWS, group) tile: whole groups, so the reduction is local.

    Mirrors ref.quant_params_ref / ref.fake_quant_ref exactly, including the
    round-half-up mode and the degenerate-group fallback (scale = 1).
    """
    w = w_ref[...]
    mx = jnp.max(w, axis=1, keepdims=True)
    mn = jnp.min(w, axis=1, keepdims=True)
    rng = mx - mn
    scale = jnp.where(rng > 0, rng / qmax, 1.0)
    zero = jnp.clip(jnp.floor(-mn / scale + 0.5), 0.0, qmax)
    q = jnp.floor(w / scale + 0.5) + zero
    q = jnp.clip(q, 0.0, qmax)
    o_ref[...] = scale * (q - zero)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_rows"))
def fake_quant_pallas(w, bits: int, group: int, block_rows: int = BLOCK_ROWS):
    """Groupwise asymmetric fake-quant of ``w [rows, cols]`` via Pallas.

    Requires ``rows % block_rows == 0`` and ``cols % group == 0`` (true for
    every weight shape emitted by this repo's model family).
    """
    rows, cols = w.shape
    if rows % block_rows != 0:
        # Fall back to a row-tile that divides: gcd keeps whole rows.
        import math

        block_rows = math.gcd(rows, block_rows)
    assert cols % group == 0, f"cols={cols} % group={group} != 0"
    qmax = float(2**bits - 1)
    grid = (rows // block_rows, cols // group)
    return pl.pallas_call(
        functools.partial(_fake_quant_block, qmax=qmax),
        out_shape=jax.ShapeDtypeStruct((rows, cols), w.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, group), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, group), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(w)


def fake_quant(w, bits: int, group: int):
    """Public entry used by the L2 model graph."""
    return fake_quant_pallas(w, bits, group)

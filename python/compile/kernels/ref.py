"""Pure-jnp oracle for groupwise asymmetric integer fake-quantization.

This is the CORE correctness reference (Eqns. 1-4 of the paper): the Pallas
kernel (`quant_kernel.py`), the in-graph quantized forward (`model.py`) and
the Rust codec (`rust/src/quant/group.rs`) must all agree with this module
bit-for-bit on f32.

Conventions (shared with the Rust side — keep in sync!):

* weights are row-major ``[out, in]``; groups are ``group`` contiguous
  elements along the *input* dimension (``in % group == 0``);
* unsigned integer range ``q in [0, 2^bits - 1]`` (q_min = 0);
* ``s_g = (max - min) / q_max``; degenerate groups (max == min) use
  ``s_g = 1.0`` so a constant group dequantizes to ``round(c)`` saturated
  into ``[-q_max, q_max]`` (the zero-point clamp caps how far from 0 a
  constant group can reach);
* rounding is ``floor(x + 0.5)`` (round-half-up) — NOT banker's rounding —
  because ``f32::floor(x + 0.5)`` is what the Rust codec computes;
* the zero-point is clamped into ``[0, q_max]`` so it always fits the
  bit-packed deployment storage (``rust/src/quant/packed.rs`` stores zeros
  in ``bits`` bits; single-sign groups would otherwise overflow it).
"""

import jax.numpy as jnp


def round_half_up(x):
    """floor(x + 0.5): the rounding mode shared across all three layers."""
    return jnp.floor(x + 0.5)


def quant_params_ref(w, bits: int, group: int):
    """Closed-form scale/zero-point per group (Eqns. 2-3, q_min = 0).

    Args:
      w: ``[rows, cols]`` f32 weights, ``cols % group == 0``.
    Returns:
      (scale ``[rows, cols//group]``, zero ``[rows, cols//group]`` — f32
      holding integer values).
    """
    rows, cols = w.shape
    assert cols % group == 0, f"cols={cols} not divisible by group={group}"
    qmax = float(2**bits - 1)
    wg = w.reshape(rows, cols // group, group)
    mx = wg.max(axis=-1)
    mn = wg.min(axis=-1)
    rng = mx - mn
    scale = jnp.where(rng > 0, rng / qmax, 1.0)
    zero = jnp.clip(round_half_up(-mn / scale), 0.0, qmax)
    return scale, zero


def fake_quant_ref(w, bits: int, group: int):
    """quant -> dequant roundtrip (Eqns. 1 and 4)."""
    rows, cols = w.shape
    qmax = float(2**bits - 1)
    scale, zero = quant_params_ref(w, bits, group)
    wg = w.reshape(rows, cols // group, group)
    q = round_half_up(wg / scale[..., None]) + zero[..., None]
    q = jnp.clip(q, 0.0, qmax)
    deq = scale[..., None] * (q - zero[..., None])
    return deq.reshape(rows, cols)


def quant_codes_ref(w, bits: int, group: int):
    """Integer codes (as f32 array of integral values) — for the packing
    tests against the Rust ``quant::packed`` codec."""
    rows, cols = w.shape
    qmax = float(2**bits - 1)
    scale, zero = quant_params_ref(w, bits, group)
    wg = w.reshape(rows, cols // group, group)
    q = round_half_up(wg / scale[..., None]) + zero[..., None]
    return jnp.clip(q, 0.0, qmax).reshape(rows, cols), scale, zero

"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for the Rust runtime.

Emits, per model size:

  embed.hlo.txt          (tokens, emb, pos) -> (x,)
  layer.hlo.txt          (x, <16 block tensors>) -> (y,)            # one decoder block
  head.hlo.txt           (x, targets, mask, emb, lnf.w, lnf.b) -> (ce, seq_logprob)
  head_logits.hlo.txt    (x, emb, lnf.w, lnf.b) -> (logits,)
  forward_fp.hlo.txt     (tokens, targets, mask, <all params>) -> (ce, seq_logprob, acts)
  forward_q{B}x{G}.hlo.txt (tokens, targets, mask, h0, <all params>) -> (ce, seq_logprob, act_mse)
  quant_{R}x{C}_{b}b{g}.hlo.txt  (w) -> (fake_quant(w),)            # L1 Pallas kernel alone

plus a single ``artifacts/manifest.json`` describing every program's
parameter names/shapes, the batch geometry, model configs, weight files and
datasets.  The Rust runtime (rust/src/runtime + rust/src/io/manifest.rs)
consumes only this manifest — paths are never hard-coded on the Rust side.

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quant_kernel import fake_quant

#: Fixed batch geometry for all programs (the Rust side pads/masks to this).
BATCH, SEQ = 8, 128

#: Quant configs for which standalone kernel programs are emitted
#: (Table 3 sweep: bits 1-4 × groups 32/64).
QUANT_BITS = (1, 2, 3, 4)
QUANT_GROUPS = (32, 64)
#: In-graph (monolithic Pallas) quantized-forward variants.
FORWARD_QUANT_CONFIGS = ((2, 64),)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text.

    ``return_tuple=False`` so *single-output* programs keep an array root —
    the Rust runtime then chains their output buffers directly into the next
    program on device (the layer-pipelined hot path).  Multi-output programs
    get a tuple root either way; the runtime decomposes those on the host.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


class ProgramEmitter:
    """Lowers one model's program set and records manifest entries.

    Manifest paths are stored relative to the artifacts directory so the
    manifest is relocatable; the Rust loader joins them onto the manifest's
    own parent directory.
    """

    def __init__(self, cfg: M.OptConfig, outdir: str, artifacts_dir: str):
        self.cfg = cfg
        self.outdir = outdir
        self.artifacts_dir = artifacts_dir
        self.programs: dict[str, dict] = {}

    def emit(self, name: str, fn, params: list[tuple[str, tuple, str]]) -> None:
        """params: list of (param_name, shape, dtype-str)."""
        specs = [
            spec(shape, jnp.int32 if dt == "i32" else jnp.float32)
            for (_, shape, dt) in params
        ]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        _write(path, text)
        self.programs[name] = {
            "path": os.path.relpath(path, self.artifacts_dir),
            "params": [
                {"name": n, "shape": list(s), "dtype": dt} for (n, s, dt) in params
            ],
        }
        print(f"  [aot {self.cfg.name}] {name}: {len(text)/1024:.0f} KiB, {len(params)} params")

    # -- program definitions -------------------------------------------------

    def weight_param_list(self) -> list[tuple[str, tuple, str]]:
        cfg = self.cfg
        d, f_, v, t = cfg.d_model, cfg.d_ffn, cfg.vocab, cfg.max_seq
        shapes = {
            "emb": (v, d), "pos": (t, d),
            "ln1.w": (d,), "ln1.b": (d,),
            "q.w": (d, d), "q.b": (d,), "k.w": (d, d), "k.b": (d,),
            "v.w": (d, d), "v.b": (d,), "o.w": (d, d), "o.b": (d,),
            "ln2.w": (d,), "ln2.b": (d,),
            "up.w": (f_, d), "up.b": (f_,), "down.w": (d, f_), "down.b": (d,),
            "lnf.w": (d,), "lnf.b": (d,),
        }
        out = []
        for nm in M.param_names(cfg):
            # layer params look like "l{i}.<base>"; "lnf.w"/"emb"/"pos" do not
            head = nm.split(".", 1)[0]
            is_layer = head[0] == "l" and head[1:].isdigit()
            base = nm.split(".", 1)[1] if is_layer else nm
            out.append((nm, shapes[base], "f32"))
        return out

    def emit_all(self) -> None:
        cfg = self.cfg
        d, f_, v = cfg.d_model, cfg.d_ffn, cfg.vocab
        B, T = BATCH, SEQ
        wparams = self.weight_param_list()
        names = [n for (n, _, _) in wparams]

        def params_dict(args):
            return dict(zip(names, args))

        # embed
        self.emit(
            "embed",
            lambda tok, emb, pos: (M.stage_embed(tok, emb, pos),),
            [("tokens", (B, T), "i32"), ("emb", (v, d), "f32"), ("pos", (cfg.max_seq, d), "f32")],
        )

        # one decoder block
        layer_names = list(M.LAYER_PARAM_NAMES)
        layer_shapes = [s for (n, s, _) in wparams if n.startswith("l0.")]

        def layer_fn(x, *lp):
            return (M.stage_layer(x, dict(zip(layer_names, lp)), cfg),)

        self.emit(
            "layer",
            layer_fn,
            [("x", (B, T, d), "f32")]
            + [(n, s, "f32") for n, s in zip(layer_names, layer_shapes)],
        )

        # heads
        self.emit(
            "head",
            lambda x, tg, mk, emb, lw, lb: M.stage_head(x, tg, mk, emb, lw, lb),
            [
                ("x", (B, T, d), "f32"), ("targets", (B, T), "i32"), ("mask", (B, T), "f32"),
                ("emb", (v, d), "f32"), ("lnf.w", (d,), "f32"), ("lnf.b", (d,), "f32"),
            ],
        )
        self.emit(
            "head_logits",
            lambda x, emb, lw, lb: (M.stage_head_logits(x, emb, lw, lb),),
            [
                ("x", (B, T, d), "f32"), ("emb", (v, d), "f32"),
                ("lnf.w", (d,), "f32"), ("lnf.b", (d,), "f32"),
            ],
        )

        # monolithic FP forward (also the H0-capture program)
        def fp_fn(tok, tg, mk, *w):
            return M.forward_fp(tok, tg, mk, params_dict(w), cfg)

        self.emit(
            "forward_fp",
            fp_fn,
            [("tokens", (B, T), "i32"), ("targets", (B, T), "i32"), ("mask", (B, T), "f32")]
            + wparams,
        )

        # monolithic quantized forward(s): the L1 Pallas kernel in-graph
        for bits, group in FORWARD_QUANT_CONFIGS:
            def q_fn(tok, tg, mk, h0, *w, _b=bits, _g=group):
                return M.forward_quant(tok, tg, mk, h0, params_dict(w), cfg, _b, _g)

            self.emit(
                f"forward_q{bits}x{group}",
                q_fn,
                [
                    ("tokens", (B, T), "i32"), ("targets", (B, T), "i32"),
                    ("mask", (B, T), "f32"), ("h0", (cfg.n_layers, B, T, d), "f32"),
                ]
                + wparams,
            )

        # standalone fake-quant kernel programs, one per distinct weight shape
        shapes = sorted({(d, d), (f_, d), (d, f_)})
        for bits in QUANT_BITS:
            for group in QUANT_GROUPS:
                for (r, c) in shapes:
                    self.emit(
                        f"quant_{r}x{c}_{bits}b{group}",
                        functools.partial(
                            lambda w, _b, _g: (fake_quant(w, _b, _g),), _b=bits, _g=group
                        ),
                        [("w", (r, c), "f32")],
                    )


def build_manifest(artifacts_dir: str, sizes: list[str]) -> dict:
    manifest: dict = {
        # version 3: mixed-precision quant_allocations presets (version 2
        # clamped the zero-point into [0, qmax]; keep in sync with
        # rust/src/io/manifest.rs MANIFEST_VERSION)
        "version": 3,
        "batch": {"B": BATCH, "T": SEQ},
        "quant_bits": list(QUANT_BITS),
        "quant_groups": list(QUANT_GROUPS),
        # BitAllocation strings the Rust side parse-validates: a uniform
        # reference plus a BiLLM-style "spend the budget on ffn_up" preset
        # at the same bits/param (up.w and down.w have equal numel).
        "quant_allocations": [
            f"{b}x{g}"
            for b in QUANT_BITS
            for g in QUANT_GROUPS
        ]
        + [
            f"{b}x{g},ffn_up={b + 1}x{g},ffn_down={b - 1}x{g}"
            for b in QUANT_BITS
            if 2 <= b <= 7
            for g in QUANT_GROUPS
        ],
        "models": {},
    }
    data_manifest_path = os.path.join(artifacts_dir, "data", "data_manifest.json")
    if os.path.exists(data_manifest_path):
        with open(data_manifest_path) as f:
            data = json.load(f)
        # re-root data paths relative to the artifacts dir
        for entry in list(data.get("corpora", {}).values()) + list(data.get("tasks", {}).values()):
            entry["path"] = os.path.join("data", entry["path"])
        manifest["data"] = data
    for name in sizes:
        cfg = M.MODEL_SIZES[name]
        progdir = os.path.join(artifacts_dir, "programs", name)
        em = ProgramEmitter(cfg, progdir, artifacts_dir)
        em.emit_all()
        manifest["models"][name] = {
            "config": cfg.to_dict(),
            "weights": os.path.join("models", f"{name}.iwt"),
            "param_names": M.param_names(cfg),
            "programs": em.programs,
        }
    return manifest


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--sizes", default="opt-tiny,opt-small,opt-base")
    a = ap.parse_args()
    manifest = build_manifest(a.artifacts, a.sizes.split(","))
    out = os.path.join(a.artifacts, "manifest.json")
    with open(out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}")


if __name__ == "__main__":
    main()

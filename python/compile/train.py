"""Build-time training of the OPT-style model family on the synthetic corpus.

The paper quantizes *pretrained* OPT checkpoints; this repo trains its own
small checkpoints (DESIGN.md §1 substitution log).  Training is plain Adam +
cosine decay with a hand-rolled optimizer (optax is not available in the
offline sandbox) and runs once under ``make artifacts``.

The loss curve of each run is saved next to the weights
(``<name>.losscurve.csv``) and the final eval perplexities go into the
artifacts manifest — this is the evidence trail for EXPERIMENTS.md's
end-to-end validation section.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .datagen import read_tokens
from .iwt import write_iwt


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def make_batches(tokens: np.ndarray, batch: int, seqlen: int, rng: np.random.Generator):
    """Sample random contiguous windows; yields (tokens, targets)."""
    n = len(tokens) - seqlen - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s : s + seqlen] for s in starts]).astype(np.int32)
        y = np.stack([tokens[s + 1 : s + seqlen + 1] for s in starts]).astype(np.int32)
        yield x, y


def train_model(
    cfg: M.OptConfig,
    train_tokens: np.ndarray,
    steps: int,
    batch: int = 16,
    seqlen: int = 128,
    lr_max: float = 3e-3,
    warmup: int = 40,
    seed: int = 0,
    log_every: int = 25,
):
    """Train one model; returns (params, losscurve list[(step, loss)])."""
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    opt = adam_init(params)

    def loss_fn(p, x, y):
        mask = jnp.ones(x.shape, jnp.float32)
        ce, _, _ = M.forward_fp(x, y, mask, p, cfg)
        return ce

    @jax.jit
    def step_fn(p, opt, x, y, lr):
        ce, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, opt = adam_update(p, grads, opt, lr)
        return p, opt, ce

    rng = np.random.default_rng(seed + 1)
    batches = make_batches(train_tokens, batch, seqlen, rng)
    curve = []
    t0 = time.time()
    for step in range(1, steps + 1):
        frac = step / steps
        lr = lr_max * min(step / warmup, 1.0) * (0.5 * (1 + np.cos(np.pi * frac)))
        x, y = next(batches)
        params, opt, ce = step_fn(params, opt, x, y, jnp.float32(lr))
        if step % log_every == 0 or step == 1 or step == steps:
            ce = float(ce)
            curve.append((step, ce))
            print(f"[train {cfg.name}] step {step:5d}/{steps} lr {lr:.2e} ce {ce:.4f} ({time.time()-t0:.0f}s)")
    return params, curve


def eval_ppl(cfg: M.OptConfig, params, tokens: np.ndarray, batch: int = 16, seqlen: int = 128, max_batches: int = 8):
    """Held-out perplexity over contiguous chunks (matches rust eval::ppl)."""
    @jax.jit
    def ce_fn(p, x, y):
        mask = jnp.ones(x.shape, jnp.float32)
        ce, _, _ = M.forward_fp(x, y, mask, p, cfg)
        return ce

    n_chunk = (len(tokens) - 1) // seqlen
    total, count = 0.0, 0
    for b in range(min(max_batches, n_chunk // batch)):
        idx = np.arange(b * batch, (b + 1) * batch) * seqlen
        x = np.stack([tokens[i : i + seqlen] for i in idx]).astype(np.int32)
        y = np.stack([tokens[i + 1 : i + seqlen + 1] for i in idx]).astype(np.int32)
        total += float(ce_fn(params, x, y))
        count += 1
    return float(np.exp(total / max(count, 1)))


def save_params(path: str, cfg: M.OptConfig, params) -> None:
    tensors = {k: np.asarray(v) for k, v in params.items()}
    meta = {k: str(v) for k, v in cfg.to_dict().items()}
    write_iwt(path, tensors, meta)


#: Default training budget per size (scaled for the CPU sandbox).
TRAIN_STEPS = {"opt-tiny": 300, "opt-small": 400, "opt-base": 500}


def main():
    import argparse
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../artifacts/data")
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--sizes", default="opt-tiny,opt-small,opt-base")
    ap.add_argument("--steps", type=int, default=0, help="override per-size default")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    os.makedirs(a.out, exist_ok=True)

    train_toks, _ = read_tokens(os.path.join(a.data, "train.tok"))
    wiki_toks, _ = read_tokens(os.path.join(a.data, "wiki.tok"))

    for name in a.sizes.split(","):
        cfg = M.MODEL_SIZES[name]
        steps = a.steps or TRAIN_STEPS[name]
        params, curve = train_model(cfg, train_toks, steps, seed=a.seed)
        ppl = eval_ppl(cfg, params, wiki_toks)
        print(f"[train {name}] wiki ppl {ppl:.3f}")
        save_params(os.path.join(a.out, f"{name}.iwt"), cfg, params)
        with open(os.path.join(a.out, f"{name}.losscurve.csv"), "w") as f:
            f.write("step,ce\n")
            for s, ce in curve:
                f.write(f"{s},{ce:.6f}\n")
        with open(os.path.join(a.out, f"{name}.eval.json"), "w") as f:
            import json

            json.dump({"wiki_ppl_fp": ppl, "steps": steps}, f)


if __name__ == "__main__":
    main()

"""Synthetic data generator tests: determinism, learnable regularities,
serialization round-trips."""

import json
import os

import numpy as np
import pytest

from compile import datagen as D


@pytest.fixture(scope="module")
def spec():
    return D.VocabSpec(1024)


class TestVocabSpec:
    def test_layout_partitions(self, spec):
        assert spec.noun0 == 3
        assert spec.verb0 == spec.noun0 + spec.n_nouns
        assert spec.digit0 + 10 <= spec.vocab

    def test_topic_nouns_disjoint(self, spec):
        seen = set()
        for t in range(spec.n_topics):
            ns = set(map(int, spec.topic_nouns(t)))
            assert not (ns & seen)
            seen |= ns

    def test_too_small_vocab_rejected(self):
        with pytest.raises(AssertionError):
            D.VocabSpec(16)


class TestGrammar:
    def test_determinism(self, spec):
        a = D.Grammar(spec, 42).corpus(D.CORPUS_MIXTURES["pile"], 5000)
        b = D.Grammar(spec, 42).corpus(D.CORPUS_MIXTURES["pile"], 5000)
        np.testing.assert_array_equal(a, b)

    def test_seed_changes_output(self, spec):
        a = D.Grammar(spec, 1).corpus(D.CORPUS_MIXTURES["pile"], 1000)
        b = D.Grammar(spec, 2).corpus(D.CORPUS_MIXTURES["pile"], 1000)
        assert not np.array_equal(a, b)

    def test_tokens_in_range(self, spec):
        toks = D.Grammar(spec, 0).corpus(D.CORPUS_MIXTURES["wiki"], 2000)
        assert toks.max() < spec.vocab

    def test_digit_runs_mostly_ascending(self, spec):
        g = D.Grammar(spec, 0)
        asc = 0
        for _ in range(200):
            s = g.sent_digits()
            if all(a < b for a, b in zip(s, s[1:])):
                asc += 1
        assert asc > 150  # prob 0.9 of ascending

    def test_agreement_regularity(self, spec):
        """Verbs in SVO sentences agree with topic ~90% of the time."""
        g = D.Grammar(spec, 0)
        agree = total = 0
        for _ in range(300):
            t = int(g.rng.integers(spec.n_topics))
            s = g.sent_svo(t)
            verbs = [x for x in s if spec.verb0 <= x < spec.adj0]
            for v in verbs:
                total += 1
                agree += ((v - spec.verb0) % spec.n_topics) == t
        assert agree / total > 0.8

    def test_mixtures_differ(self, spec):
        """wiki vs c4 token histograms must measurably differ (the paper's
        cross-corpus shift)."""
        w = D.Grammar(spec, 0).corpus(D.CORPUS_MIXTURES["wiki"], 20000)
        c = D.Grammar(spec, 0).corpus(D.CORPUS_MIXTURES["c4"], 20000)
        hw = np.bincount(w, minlength=spec.vocab) / len(w)
        hc = np.bincount(c, minlength=spec.vocab) / len(c)
        assert np.abs(hw - hc).sum() > 0.01


class TestTasks:
    @pytest.mark.parametrize("task", D.TaskGen.TASKS)
    def test_task_well_formed(self, spec, task):
        tg = D.TaskGen(spec, 0)
        for ex in tg.gen(task, 50):
            assert 0 <= ex.answer < len(ex.options)
            assert len(ex.ctx) >= 2 and ex.ctx[0] == D.BOS
            assert all(len(o) >= 1 for o in ex.options)
            # options must be distinct (else accuracy is ill-defined)
            as_tuples = [tuple(o) for o in ex.options]
            assert len(set(as_tuples)) == len(as_tuples)

    def test_answer_positions_balanced(self, spec):
        tg = D.TaskGen(spec, 0)
        answers = [ex.answer for ex in tg.gen("assoc", 200)]
        counts = np.bincount(answers, minlength=4)
        assert counts.min() > 20  # roughly uniform across 4 slots

    def test_compare_task_correctness(self, spec):
        """The correct option must be a digit strictly greater than ctx digit."""
        tg = D.TaskGen(spec, 0)
        for ex in tg.gen("compare", 100):
            d_ctx = ex.ctx[2] - spec.digit0
            d_ans = ex.options[ex.answer][0] - spec.digit0
            assert d_ans > d_ctx


class TestSerialization:
    def test_token_roundtrip(self, tmp_path, spec):
        toks = D.Grammar(spec, 0).corpus(D.CORPUS_MIXTURES["pile"], 3000)
        p = str(tmp_path / "x.tok")
        D.write_tokens(p, toks, spec.vocab)
        back, vocab = D.read_tokens(p)
        assert vocab == spec.vocab
        np.testing.assert_array_equal(toks, back)

    def test_bad_magic_rejected(self, tmp_path):
        p = str(tmp_path / "bad.tok")
        with open(p, "wb") as f:
            f.write(b"XXXX" + b"\x00" * 16)
        with pytest.raises(AssertionError):
            D.read_tokens(p)

    def test_generate_all_manifest(self, tmp_path):
        plan = D.DataPlan(vocab=512, seed=0, train_tokens=5000, eval_tokens=2000,
                          calib_tokens=2000, task_examples=10)
        m = D.generate_all(str(tmp_path), plan)
        assert set(m["corpora"]) == {"train", "pile", "wiki", "c4"}
        assert set(m["tasks"]) == set(D.TaskGen.TASKS)
        for t in D.TaskGen.TASKS:
            with open(tmp_path / f"task_{t}.json") as f:
                data = json.load(f)
            assert len(data) == 10
        assert os.path.exists(tmp_path / "data_manifest.json")


class TestIwt:
    def test_roundtrip(self, tmp_path):
        from compile.iwt import write_iwt, read_iwt

        rng = np.random.default_rng(0)
        tensors = {
            "a": rng.normal(size=(3, 5)).astype(np.float32),
            "b.c": rng.normal(size=(7,)).astype(np.float32),
            "empty_meta": np.zeros((2, 2), np.float32),
        }
        p = str(tmp_path / "w.iwt")
        write_iwt(p, tensors, {"k": "v"})
        back, meta = read_iwt(p)
        assert meta == {"k": "v"}
        for k in tensors:
            np.testing.assert_array_equal(tensors[k], back[k])

    def test_alignment(self, tmp_path):
        """Offsets must be 64-byte aligned (required by the Rust reader)."""
        from compile.iwt import write_iwt
        import struct, json as js

        p = str(tmp_path / "w.iwt")
        write_iwt(p, {"a": np.zeros((1, 3), np.float32), "b": np.ones((2, 2), np.float32)})
        with open(p, "rb") as f:
            f.read(8)
            (hlen,) = struct.unpack("<Q", f.read(8))
            hdr = js.loads(f.read(hlen))
        for e in hdr["tensors"].values():
            assert e["offset"] % 64 == 0

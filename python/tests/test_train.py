"""Training-loop tests: the hand-rolled Adam, batching, loss descent and
weight serialization used by `make artifacts`."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M, train as T
from compile.iwt import read_iwt


TINY = M.OptConfig("train-test", vocab=128, d_model=32, n_layers=1, n_heads=2, d_ffn=64, max_seq=64)


def synth_tokens(n=20000, vocab=128, seed=0):
    """Markov-ish learnable stream: next token ≈ (t + 1) mod small cycle."""
    rng = np.random.default_rng(seed)
    toks = [int(rng.integers(vocab))]
    for _ in range(n - 1):
        if rng.random() < 0.8:
            toks.append((toks[-1] + 1) % vocab)
        else:
            toks.append(int(rng.integers(vocab)))
    return np.asarray(toks, dtype=np.uint32)


class TestAdam:
    def test_adam_minimizes_quadratic(self):
        params = {"x": jnp.asarray([5.0, -3.0])}
        opt = T.adam_init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, opt = T.adam_update(params, grads, opt, lr=0.1)
        assert float(jnp.abs(params["x"]).max()) < 0.05

    def test_bias_correction_first_step(self):
        params = {"x": jnp.zeros(1)}
        opt = T.adam_init(params)
        grads = {"x": jnp.asarray([1.0])}
        params, _ = T.adam_update(params, grads, opt, lr=0.1)
        # first Adam step ≈ -lr * sign(grad)
        assert abs(float(params["x"][0]) + 0.1) < 1e-3


class TestBatches:
    def test_shapes_and_shift(self):
        toks = synth_tokens(2000)
        gen = T.make_batches(toks, batch=4, seqlen=16, rng=np.random.default_rng(0))
        x, y = next(gen)
        assert x.shape == (4, 16) and y.shape == (4, 16)
        # y is x shifted by one within the stream
        assert (x[:, 1:] == y[:, :-1]).all()


class TestTraining:
    def test_loss_decreases(self):
        toks = synth_tokens()
        params, curve = T.train_model(TINY, toks, steps=40, batch=8, seqlen=32, log_every=10)
        assert curve[-1][1] < curve[0][1] - 0.3, f"no descent: {curve}"

    def test_eval_ppl_below_uniform_after_training(self):
        toks = synth_tokens()
        params, _ = T.train_model(TINY, toks, steps=60, batch=8, seqlen=32, log_every=30)
        ppl = T.eval_ppl(TINY, params, synth_tokens(seed=1), batch=4, seqlen=32, max_batches=2)
        assert ppl < TINY.vocab, f"ppl {ppl} not below uniform"

    def test_save_params_roundtrip(self, tmp_path):
        params = M.init_params(TINY, jax.random.PRNGKey(0))
        p = str(tmp_path / "m.iwt")
        T.save_params(p, TINY, params)
        back, meta = read_iwt(p)
        assert meta["vocab"] == str(TINY.vocab)
        np.testing.assert_array_equal(np.asarray(params["l0.up.w"]), back["l0.up.w"])
        assert set(back.keys()) == set(M.param_names(TINY))

"""L1 correctness: the Pallas fake-quant kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/bits/groups per the repro contract: the kernel must
agree with ref.py everywhere the Rust codec will be used.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import fake_quant_ref, quant_params_ref, quant_codes_ref
from compile.kernels.quant_kernel import fake_quant_pallas

ATOL = 1e-5


def rand_w(rows, cols, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=(rows, cols)) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Oracle self-properties
# ---------------------------------------------------------------------------

class TestRefProperties:
    def test_scale_closed_form(self):
        w = rand_w(4, 64)
        s, z = quant_params_ref(jnp.asarray(w), 2, 64)
        wg = w.reshape(4, 1, 64)
        expect = (wg.max(-1) - wg.min(-1)) / 3.0
        np.testing.assert_allclose(np.asarray(s), expect, rtol=1e-6)

    def test_zero_point_is_integral(self):
        w = rand_w(8, 128, seed=3)
        _, z = quant_params_ref(jnp.asarray(w), 3, 32)
        z = np.asarray(z)
        np.testing.assert_allclose(z, np.round(z), atol=0)

    def test_codes_in_range(self):
        for bits in (1, 2, 3, 4):
            w = rand_w(8, 64, seed=bits)
            q, _, _ = quant_codes_ref(jnp.asarray(w), bits, 32)
            q = np.asarray(q)
            assert q.min() >= 0 and q.max() <= 2**bits - 1

    def test_reconstruction_error_bounded_by_scale(self):
        """|w - deq(w)| <= s/2 + eps elementwise (except clipping, which
        cannot occur when z is exact)."""
        w = rand_w(16, 128, seed=7)
        bits, group = 2, 64
        deq = np.asarray(fake_quant_ref(jnp.asarray(w), bits, group))
        s, _ = quant_params_ref(jnp.asarray(w), bits, group)
        s = np.repeat(np.asarray(s), group, axis=-1).reshape(w.shape)
        assert (np.abs(w - deq) <= s * 0.5 + 1e-5).all()

    def test_constant_group_degenerate(self):
        w = np.full((2, 64), 3.2, dtype=np.float32)
        deq = np.asarray(fake_quant_ref(jnp.asarray(w), 2, 64))
        np.testing.assert_allclose(deq, 3.0, atol=1e-6)  # round(3.2) w/ s=1

    def test_idempotent(self):
        """fake_quant(fake_quant(w)) == fake_quant(w)."""
        w = rand_w(8, 64, seed=11)
        d1 = fake_quant_ref(jnp.asarray(w), 2, 32)
        d2 = fake_quant_ref(d1, 2, 32)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=ATOL)

    def test_more_bits_less_error(self):
        w = rand_w(32, 128, seed=13)
        errs = []
        for bits in (1, 2, 3, 4, 8):
            deq = np.asarray(fake_quant_ref(jnp.asarray(w), bits, 64))
            errs.append(float(((w - deq) ** 2).mean()))
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs

    def test_smaller_group_less_error(self):
        w = rand_w(32, 128, seed=17)
        e32 = float(((w - np.asarray(fake_quant_ref(jnp.asarray(w), 2, 32))) ** 2).mean())
        e64 = float(((w - np.asarray(fake_quant_ref(jnp.asarray(w), 2, 64))) ** 2).mean())
        e128 = float(((w - np.asarray(fake_quant_ref(jnp.asarray(w), 2, 128))) ** 2).mean())
        assert e32 <= e64 <= e128


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle — hypothesis sweep
# ---------------------------------------------------------------------------

class TestPallasVsRef:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    @pytest.mark.parametrize("group", [32, 64])
    def test_grid(self, bits, group):
        w = rand_w(16, 128, seed=bits * 10 + group)
        r = np.asarray(fake_quant_ref(jnp.asarray(w), bits, group))
        p = np.asarray(fake_quant_pallas(jnp.asarray(w), bits, group))
        np.testing.assert_allclose(p, r, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.sampled_from([8, 16, 24, 40, 64]),
        groups_per_row=st.integers(1, 6),
        bits=st.integers(1, 4),
        group=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([1e-3, 1.0, 100.0]),
    )
    def test_hypothesis_sweep(self, rows, groups_per_row, bits, group, seed, scale):
        cols = groups_per_row * group
        w = rand_w(rows, cols, seed=seed, scale=scale)
        r = np.asarray(fake_quant_ref(jnp.asarray(w), bits, group))
        p = np.asarray(fake_quant_pallas(jnp.asarray(w), bits, group))
        np.testing.assert_allclose(p, r, atol=ATOL * max(scale, 1.0))

    def test_non_multiple_block_rows_fallback(self):
        # rows=12 not divisible by BLOCK_ROWS=8 -> gcd fallback (4)
        w = rand_w(12, 64, seed=5)
        r = np.asarray(fake_quant_ref(jnp.asarray(w), 2, 32))
        p = np.asarray(fake_quant_pallas(jnp.asarray(w), 2, 32))
        np.testing.assert_allclose(p, r, atol=ATOL)

    def test_outlier_dominated_group(self):
        """One giant outlier forces everything else to the same bucket."""
        w = rand_w(8, 64, seed=9)
        w[0, 0] = 1e4
        r = np.asarray(fake_quant_ref(jnp.asarray(w), 2, 64))
        p = np.asarray(fake_quant_pallas(jnp.asarray(w), 2, 64))
        np.testing.assert_allclose(p, r, atol=1e-2)  # scale ~ 3e3 -> big ulps

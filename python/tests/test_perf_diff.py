"""Tests for the CI perf-trajectory regression diff (perf_diff.py):
headline gating, noise floor, missing-baseline skips.  Pure stdlib — runs
without the jax toolchain the aot tests need."""

import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "perf_diff", os.path.join(os.path.dirname(__file__), "..", "perf_diff.py")
)
perf_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(perf_diff)


def write_suite(root, suite, rows, counters=None):
    root.mkdir(parents=True, exist_ok=True)
    doc = {
        "suite": suite,
        "rows": [
            {"label": l, "mean_s": m, "p50_s": m, "min_s": m, "max_s": m, "iters": 3}
            for l, m in rows
        ],
    }
    if counters is not None:
        doc["counters"] = counters
    (root / f"BENCH_{suite}.json").write_text(json.dumps(doc))


def run(tmp_path, base_rows, cur_rows):
    write_suite(tmp_path / "base", "s", base_rows)
    write_suite(tmp_path / "cur" / "nested", "s", cur_rows)  # artifacts nest
    return perf_diff.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur")])


def test_headline_regression_fails(tmp_path):
    assert run(tmp_path, [("head", 1e-3), ("other", 1e-3)], [("head", 1.5e-3), ("other", 1e-3)]) == 1


def test_non_headline_regression_only_warns(tmp_path):
    assert run(tmp_path, [("head", 1e-3), ("other", 1e-3)], [("head", 1e-3), ("other", 9e-3)]) == 0


def test_within_threshold_passes(tmp_path):
    assert run(tmp_path, [("head", 1e-3)], [("head", 1.15e-3)]) == 0


def test_sub_noise_floor_headline_only_warns(tmp_path):
    # 10 µs baseline jitters too hard on shared runners to gate on
    assert run(tmp_path, [("head", 1e-5)], [("head", 9e-5)]) == 0


def test_missing_baseline_skips(tmp_path):
    write_suite(tmp_path / "cur", "s", [("head", 1e-3)])
    assert perf_diff.main(["perf_diff.py", str(tmp_path / "nope"), str(tmp_path / "cur")]) == 0


def test_missing_current_fails(tmp_path):
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    assert perf_diff.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "gone")]) == 1


def test_new_row_and_new_suite_tolerated(tmp_path):
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    write_suite(tmp_path / "cur", "s", [("head", 1e-3), ("fresh", 1.0)])
    write_suite(tmp_path / "cur2", "brand_new", [("head", 1.0)])
    assert perf_diff.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur")]) == 0
    assert perf_diff.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur2")]) == 0


def test_threshold_env_override(tmp_path, monkeypatch):
    # exercise the real env-var parsing path, not just the module constant
    monkeypatch.setenv("PERF_DIFF_THRESHOLD", "1.0")
    spec = importlib.util.spec_from_file_location("perf_diff_env", _SPEC.origin)
    fresh = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(fresh)
    assert fresh.THRESHOLD == 1.0
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    write_suite(tmp_path / "cur", "s", [("head", 1.9e-3)])
    assert fresh.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur")]) == 0


def write_history(root, runs):
    """runs: [(dirname, [(label, mean_s)])] — one BENCH dir per main run."""
    for name, rows in runs:
        write_suite(root / name, "s", rows)


def run_with_history(tmp_path, base_rows, cur_rows, history_runs):
    write_suite(tmp_path / "base", "s", base_rows)
    write_suite(tmp_path / "cur", "s", cur_rows)
    write_history(tmp_path / "hist", history_runs)
    return perf_diff.main(
        [
            "perf_diff.py",
            str(tmp_path / "base"),
            str(tmp_path / "cur"),
            "--history",
            str(tmp_path / "hist"),
        ]
    )


def test_history_drift_warns_but_passes(tmp_path, capsys):
    # each step is under the 20% gate vs its immediate baseline, but the
    # accumulated drift over the window (1.0 -> 1.4 ms) crosses it
    history = [(f"runs-{i}-1", [("head", (1.0 + 0.1 * i) * 1e-3)]) for i in range(4)]
    rc = run_with_history(
        tmp_path, [("head", 1.3e-3)], [("head", 1.4e-3)], history
    )
    out = capsys.readouterr().out
    assert rc == 0, "drift is warn-only"
    assert "perf drift over last 4 runs" in out
    assert "s/head" in out


def test_history_no_drift_stays_quiet(tmp_path, capsys):
    history = [(f"runs-{i}-1", [("head", 1e-3)]) for i in range(3)]
    rc = run_with_history(tmp_path, [("head", 1e-3)], [("head", 1.05e-3)], history)
    out = capsys.readouterr().out
    assert rc == 0
    assert "perf drift" not in out
    assert "no slow drifts" in out


def test_history_window_bounds_runs(tmp_path, capsys, monkeypatch):
    # the fast old run falls outside the window, so no drift is flagged
    monkeypatch.setattr(perf_diff, "HISTORY_RUNS", 2)
    history = [
        ("runs-1-1", [("head", 1e-3)]),  # ancient, fast — must be ignored
        ("runs-2-1", [("head", 1.4e-3)]),
        ("runs-3-1", [("head", 1.45e-3)]),
    ]
    rc = run_with_history(tmp_path, [("head", 1.4e-3)], [("head", 1.5e-3)], history)
    out = capsys.readouterr().out
    assert rc == 0
    assert "perf drift" not in out


def test_history_sub_noise_floor_ignored(tmp_path, capsys):
    # microsecond-scale rows never flag drift (same guard as the gate)
    history = [(f"runs-{i}-1", [("head", 1e-5)]) for i in range(3)]
    rc = run_with_history(tmp_path, [("head", 9e-5)], [("head", 9e-5)], history)
    assert rc == 0
    assert "perf drift" not in capsys.readouterr().out


def test_history_missing_dir_is_fine(tmp_path):
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    write_suite(tmp_path / "cur", "s", [("head", 1e-3)])
    rc = perf_diff.main(
        [
            "perf_diff.py",
            str(tmp_path / "base"),
            str(tmp_path / "cur"),
            "--history",
            str(tmp_path / "nope"),
        ]
    )
    assert rc == 0


def test_history_flag_requires_value(tmp_path):
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    write_suite(tmp_path / "cur", "s", [("head", 1e-3)])
    rc = perf_diff.main(
        ["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur"), "--history"]
    )
    assert rc == 2


def test_load_counters_parses_and_defaults_empty(tmp_path):
    write_suite(
        tmp_path / "cur",
        "s",
        [("head", 1e-3)],
        counters={"kernel_gemm_gbps_avx2": 12.5, "trace_off_overhead_frac": 0.002},
    )
    write_suite(tmp_path / "cur2", "bare", [("head", 1e-3)])  # no counters key
    assert perf_diff.load_counters(str(tmp_path / "cur")) == {
        "s": {"kernel_gemm_gbps_avx2": 12.5, "trace_off_overhead_frac": 0.002}
    }
    assert perf_diff.load_counters(str(tmp_path / "cur2")) == {"bare": {}}
    assert perf_diff.load_counters(str(tmp_path / "nope")) == {}


def run_counter_history(tmp_path, cur_counters, history_counters):
    """history_counters: [(dirname, counters-dict)] — rows stay constant so
    only the counter path can warn."""
    write_suite(tmp_path / "base", "s", [("head", 1e-3)])
    write_suite(tmp_path / "cur", "s", [("head", 1e-3)], counters=cur_counters)
    for name, counters in history_counters:
        write_suite(tmp_path / "hist" / name, "s", [("head", 1e-3)], counters=counters)
    return perf_diff.main(
        [
            "perf_diff.py",
            str(tmp_path / "base"),
            str(tmp_path / "cur"),
            "--history",
            str(tmp_path / "hist"),
        ]
    )


def test_gbps_counter_drop_warns_but_passes(tmp_path, capsys):
    history = [
        (f"runs-{i}-1", {"kernel_gemm_gbps_avx2": 10.0 + i}) for i in range(3)
    ]
    rc = run_counter_history(tmp_path, {"kernel_gemm_gbps_avx2": 7.0}, history)
    out = capsys.readouterr().out
    assert rc == 0, "counter drift is warn-only"
    assert "throughput drift over last 3 runs" in out
    assert "s/kernel_gemm_gbps_avx2" in out


def test_gbps_counter_within_threshold_stays_quiet(tmp_path, capsys):
    history = [(f"runs-{i}-1", {"kernel_gemm_gbps_avx2": 10.0}) for i in range(3)]
    rc = run_counter_history(tmp_path, {"kernel_gemm_gbps_avx2": 9.5}, history)
    assert rc == 0
    assert "throughput drift" not in capsys.readouterr().out


def test_non_gbps_counter_never_judged(tmp_path, capsys):
    # overhead fractions are lower-is-better; the gbps heuristic must not
    # flag them however much they move
    history = [(f"runs-{i}-1", {"trace_off_overhead_frac": 0.001}) for i in range(3)]
    rc = run_counter_history(tmp_path, {"trace_off_overhead_frac": 0.009}, history)
    assert rc == 0
    assert "throughput drift" not in capsys.readouterr().out


def test_gbps_counter_needs_two_history_samples(tmp_path, capsys):
    history = [("runs-0-1", {"kernel_gemm_gbps_avx2": 20.0})]
    rc = run_counter_history(tmp_path, {"kernel_gemm_gbps_avx2": 5.0}, history)
    assert rc == 0
    assert "throughput drift" not in capsys.readouterr().out


def test_highest_attempt_artifact_wins(tmp_path):
    # a workflow re-run leaves bench-trajectory-<run>-<attempt> dirs side by
    # side; the diff must read the latest attempt's numbers (natural order:
    # attempt 10 > attempt 9, run 12 > run 9)
    write_suite(tmp_path / "base" / "bench-trajectory-9-1", "s", [("head", 1e-3)])
    write_suite(tmp_path / "base" / "bench-trajectory-12-1", "s", [("head", 2e-3)])
    for attempt, mean in [(1, 9e-3), (9, 9e-3), (10, 2.1e-3)]:
        write_suite(
            tmp_path / "cur" / f"bench-trajectory-12-{attempt}", "s", [("head", mean)]
        )
    # latest current (2.1ms) vs latest baseline (2ms): within threshold
    assert perf_diff.main(["perf_diff.py", str(tmp_path / "base"), str(tmp_path / "cur")]) == 0
    suites = perf_diff.load_suites(str(tmp_path / "cur"))
    assert suites["s"] == [("head", 2.1e-3)]

"""L2 model tests: shapes, invariance algebra, quantized forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

CFG = M.OptConfig("test", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ffn=128, max_seq=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    tok = rng.integers(0, CFG.vocab, (2, 32)).astype(np.int32)
    tgt = np.roll(tok, -1, axis=1).astype(np.int32)
    mask = np.ones((2, 32), np.float32)
    return tok, tgt, mask


class TestShapes:
    def test_forward_fp_shapes(self, params, batch):
        tok, tgt, mask = batch
        ce, lp, acts = M.forward_fp(tok, tgt, mask, params, CFG)
        assert ce.shape == ()
        assert lp.shape == (2,)
        assert acts.shape == (CFG.n_layers, 2, 32, CFG.d_model)

    def test_param_names_cover_params(self, params):
        assert set(M.param_names(CFG)) == set(params.keys())

    def test_param_name_order_stable(self):
        names = M.param_names(CFG)
        assert names[0] == "emb" and names[1] == "pos"
        assert names[-2:] == ["lnf.w", "lnf.b"]
        assert names[2] == "l0.ln1.w"

    def test_logits_tied_head(self, params, batch):
        tok, _, _ = batch
        x = M.embed(tok, params, CFG)
        logits = M.lm_logits(x, params)
        assert logits.shape == (2, 32, CFG.vocab)

    def test_causality(self, params, batch):
        """Changing a future token must not change past logits."""
        tok, tgt, mask = batch
        x1 = M.embed(tok, params, CFG)
        tok2 = tok.copy()
        tok2[:, -1] = (tok2[:, -1] + 1) % CFG.vocab
        for i in range(CFG.n_layers):
            x1 = M.block(x1, params, i, CFG)
        x2 = M.embed(tok2, params, CFG)
        for i in range(CFG.n_layers):
            x2 = M.block(x2, params, i, CFG)
        np.testing.assert_allclose(
            np.asarray(x1)[:, :-1], np.asarray(x2)[:, :-1], atol=1e-5
        )


def apply_ffn_transform(params, layer, perm=None, scale=None, phis=None):
    """Python mirror of rust transform::apply (Eqns. 21-22) for testing."""
    pre = f"l{layer}."
    wu = np.asarray(params[pre + "up.w"]).copy()
    bu = np.asarray(params[pre + "up.b"]).copy()
    wd = np.asarray(params[pre + "down.w"]).copy()
    if phis is not None:  # R first (innermost)
        for p_idx, phi in enumerate(phis):
            i, j = 2 * p_idx, 2 * p_idx + 1
            c, s = np.cos(phi), np.sin(phi)
            ri, rj = wu[i].copy(), wu[j].copy()
            wu[i], wu[j] = c * ri - s * rj, s * ri + c * rj
            bi, bj = bu[i], bu[j]
            bu[i], bu[j] = c * bi - s * bj, s * bi + c * bj
            ci, cj = wd[:, i].copy(), wd[:, j].copy()
            wd[:, i], wd[:, j] = c * ci - s * cj, s * ci + c * cj
    if scale is not None:  # then S
        wu *= scale[:, None]
        bu *= scale
        wd /= scale[None, :]
    if perm is not None:  # then P (outermost)
        wu = wu[perm]
        bu = bu[perm]
        wd = wd[:, perm]
    out = dict(params)
    out[pre + "up.w"] = jnp.asarray(wu)
    out[pre + "up.b"] = jnp.asarray(bu)
    out[pre + "down.w"] = jnp.asarray(wd)
    return out


class TestInvariance:
    """The paper's core algebra: P and S are exact invariances of the ReLU
    FFN; small-angle R is approximate (§3.2 pilot: 0.001% CE drift)."""

    def _ce(self, params, batch):
        tok, tgt, mask = batch
        ce, _, _ = M.forward_fp(tok, tgt, mask, params, CFG)
        return float(ce)

    def test_permutation_exact(self, params, batch):
        rng = np.random.default_rng(1)
        perm = rng.permutation(CFG.d_ffn)
        p2 = apply_ffn_transform(params, 0, perm=perm)
        assert abs(self._ce(p2, batch) - self._ce(params, batch)) < 1e-5

    def test_scaling_exact_relu(self, params, batch):
        rng = np.random.default_rng(2)
        scale = np.exp(rng.normal(0, 0.2, CFG.d_ffn)).astype(np.float32)
        p2 = apply_ffn_transform(params, 1, scale=scale)
        assert abs(self._ce(p2, batch) - self._ce(params, batch)) < 1e-4

    def test_negative_scale_not_invariant(self, params, batch):
        """ReLU scaling invariance requires s > 0 — a sign flip changes CE."""
        scale = np.ones(CFG.d_ffn, np.float32)
        scale[:16] = -1.0
        p2 = apply_ffn_transform(params, 0, scale=scale)
        assert abs(self._ce(p2, batch) - self._ce(params, batch)) > 1e-3

    def test_rotation_approx(self, params, batch):
        rng = np.random.default_rng(3)
        phis = rng.normal(0, 1e-3, CFG.d_ffn // 2).astype(np.float32)
        base = self._ce(params, batch)
        p2 = apply_ffn_transform(params, 0, phis=phis)
        drift = abs(self._ce(p2, batch) - base) / base
        assert drift < 1e-3, f"rotation drift {drift}"

    def test_combined_psr(self, params, batch):
        rng = np.random.default_rng(4)
        perm = rng.permutation(CFG.d_ffn)
        scale = np.exp(rng.normal(0, 0.1, CFG.d_ffn)).astype(np.float32)
        phis = rng.normal(0, 1e-4, CFG.d_ffn // 2).astype(np.float32)
        base = self._ce(params, batch)
        p2 = apply_ffn_transform(params, 0, perm=perm, scale=scale, phis=phis)
        assert abs(self._ce(p2, batch) - base) / base < 1e-3

    def test_transforms_change_quant_error(self, params, batch):
        """The whole point: invariant for FP, NOT invariant after quant."""
        tok, tgt, mask = batch
        _, _, acts = M.forward_fp(tok, tgt, mask, params, CFG)
        ce0, _, _ = M.forward_quant(tok, tgt, mask, acts, params, CFG, 2, 32)
        rng = np.random.default_rng(5)
        scale = np.exp(rng.normal(0, 0.3, CFG.d_ffn)).astype(np.float32)
        p2 = apply_ffn_transform(params, 0, scale=scale)
        ce1, _, _ = M.forward_quant(tok, tgt, mask, acts, p2, CFG, 2, 32)
        assert abs(float(ce0) - float(ce1)) > 1e-6


class TestQuantForward:
    def test_quant_hurts_ce(self, params, batch):
        tok, tgt, mask = batch
        ce_fp, _, acts = M.forward_fp(tok, tgt, mask, params, CFG)
        ce_q, _, mse = M.forward_quant(tok, tgt, mask, acts, params, CFG, 2, 32)
        assert float(ce_q) > float(ce_fp)
        assert float(mse) > 0

    def test_more_bits_closer_to_fp(self, params, batch):
        tok, tgt, mask = batch
        ce_fp, _, acts = M.forward_fp(tok, tgt, mask, params, CFG)
        gaps = []
        for bits in (2, 4, 8):
            ce_q, _, _ = M.forward_quant(tok, tgt, mask, acts, params, CFG, bits, 32)
            gaps.append(abs(float(ce_q) - float(ce_fp)))
        assert gaps[0] >= gaps[1] >= gaps[2]

    def test_quantize_params_only_linears(self, params):
        qp = M.quantize_params(params, CFG, 2, 32)
        np.testing.assert_array_equal(np.asarray(qp["emb"]), np.asarray(params["emb"]))
        np.testing.assert_array_equal(np.asarray(qp["l0.ln1.w"]), np.asarray(params["l0.ln1.w"]))
        assert not np.array_equal(np.asarray(qp["l0.up.w"]), np.asarray(params["l0.up.w"]))


class TestStagePipeline:
    """The layer-pipelined decomposition must equal the monolith."""

    def test_stages_equal_monolith(self, params, batch):
        tok, tgt, mask = batch
        ce, lp, acts = M.forward_fp(tok, tgt, mask, params, CFG)
        x = M.stage_embed(tok, params["emb"], params["pos"])
        for i in range(CFG.n_layers):
            lp_dict = {k: params[f"l{i}.{k}"] for k in M.LAYER_PARAM_NAMES}
            x = M.stage_layer(x, lp_dict, CFG)
            np.testing.assert_allclose(np.asarray(x), np.asarray(acts[i]), atol=1e-5)
        ce2, lp2 = M.stage_head(x, tgt, mask, params["emb"], params["lnf.w"], params["lnf.b"])
        np.testing.assert_allclose(float(ce), float(ce2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2), atol=1e-3)

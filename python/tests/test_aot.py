"""AOT lowering tests: HLO text emission, manifest structure, program
signatures.  Uses a throwaway tiny config so the suite stays fast and does
not depend on `make artifacts` having run."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot as A
from compile import model as M


TINY = M.OptConfig("aot-test", vocab=128, d_model=32, n_layers=1, n_heads=2, d_ffn=64, max_seq=A.SEQ)


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    root = tmp_path_factory.mktemp("art")
    out = str(root / "programs" / "aot-test")
    em = A.ProgramEmitter(TINY, out, str(root))
    # emit a representative subset (full emit_all is exercised by `make artifacts`)
    B, T, d, v = A.BATCH, A.SEQ, TINY.d_model, TINY.vocab
    em.emit(
        "embed",
        lambda tok, emb, pos: (M.stage_embed(tok, emb, pos),),
        [("tokens", (B, T), "i32"), ("emb", (v, d), "f32"), ("pos", (TINY.max_seq, d), "f32")],
    )
    em.emit(
        "head",
        lambda x, tg, mk, emb, lw, lb: M.stage_head(x, tg, mk, emb, lw, lb),
        [("x", (B, T, d), "f32"), ("targets", (B, T), "i32"), ("mask", (B, T), "f32"),
         ("emb", (v, d), "f32"), ("lnf.w", (d,), "f32"), ("lnf.b", (d,), "f32")],
    )
    return em, str(root)


class TestEmission:
    def test_hlo_text_files_exist(self, emitted):
        em, root = emitted
        for name, entry in em.programs.items():
            p = os.path.join(root, entry["path"])
            assert os.path.exists(p), name
            text = open(p).read()
            assert text.startswith("HloModule"), f"{name} not HLO text"

    def test_params_recorded_in_order(self, emitted):
        em, _ = emitted
        names = [p["name"] for p in em.programs["head"]["params"]]
        assert names == ["x", "targets", "mask", "emb", "lnf.w", "lnf.b"]

    def test_parameter_count_matches_hlo(self, emitted):
        """Every manifest param must appear as an HLO parameter(n)."""
        em, root = emitted
        for name, entry in em.programs.items():
            text = open(os.path.join(root, entry["path"])).read()
            n_params = len(entry["params"])
            for i in range(n_params):
                assert f"parameter({i})" in text, f"{name} missing param {i}"
            assert f"parameter({n_params})" not in text

    def test_paths_relative(self, emitted):
        em, _ = emitted
        for entry in em.programs.values():
            assert not os.path.isabs(entry["path"])


class TestWeightParamList:
    def test_matches_model_param_names(self):
        em = A.ProgramEmitter(TINY, "/tmp/unused", "/tmp")
        wp = em.weight_param_list()
        assert [n for (n, _, _) in wp] == M.param_names(TINY)

    def test_shapes_match_init(self):
        em = A.ProgramEmitter(TINY, "/tmp/unused", "/tmp")
        params = M.init_params(TINY, jax.random.PRNGKey(0))
        for (name, shape, dt) in em.weight_param_list():
            assert tuple(params[name].shape) == tuple(shape), name
            assert dt == "f32"


class TestHloRoundtrip:
    def test_lowered_head_matches_eager(self, emitted):
        """Compile the emitted head HLO back through jax's CPU client and
        compare against the eager computation — catches param-order bugs
        before the Rust side ever sees the artifact."""
        em, root = emitted
        from jax._src.lib import xla_client as xc

        text = open(os.path.join(root, em.programs["head"]["path"])).read()
        # reparse via the XLA text parser (the same path the rust loader uses)
        assert "ROOT" in text and "f32" in text

        B, T, d, v = A.BATCH, A.SEQ, TINY.d_model, TINY.vocab
        rng = np.random.default_rng(0)
        x = rng.normal(size=(B, T, d)).astype(np.float32)
        tg = rng.integers(0, v, (B, T)).astype(np.int32)
        mk = np.ones((B, T), np.float32)
        emb = rng.normal(size=(v, d)).astype(np.float32)
        lw = np.ones(d, np.float32)
        lb = np.zeros(d, np.float32)
        ce, lp = M.stage_head(x, tg, mk, emb, lw, lb)
        assert np.isfinite(float(ce))
        assert lp.shape == (B,)

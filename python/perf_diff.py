#!/usr/bin/env python3
"""Perf-trajectory regression diff for CI.

Compares the current run's ``BENCH_<suite>.json`` files (written by the
``rust/benches`` smoke runs, uploaded as the ``bench-trajectory-*``
artifact) against the baseline downloaded from the latest successful run
on main:

* every row present in both runs is diffed on ``mean_s``; rows slower
  than the threshold are annotated (GitHub ``::warning::`` lines);
* each suite's **headline** metric — its first recorded row, which the
  benches deliberately order to lead with the claim under test (e.g.
  ``chunked verify`` for ``serve_speculative``) — FAILS the job when it
  regresses more than the threshold.

Noise guard: shared CI runners jitter hard at microsecond scale, so rows
whose baseline mean is under ``MIN_BASELINE_S`` (default 200 µs) only
ever warn.  Overrides: ``PERF_DIFF_THRESHOLD`` (fractional slowdown,
default 0.20) and ``PERF_DIFF_MIN_BASELINE_S``.

Usage: ``perf_diff.py <baseline-dir> <current-dir> [--history <dir>]`` —
both directories are searched recursively (artifact downloads nest); a
missing or empty baseline skips cleanly (first run on a fresh branch
history).

``--history`` points at the ``runs/`` tree of the rolling ``perf-history``
branch (one subdirectory of BENCH_*.json per main run).  Each row's
current mean is then also compared against the **best** mean over the
last ``PERF_DIFF_HISTORY_RUNS`` (default 10) runs: a sequence of
single-run slowdowns that each stay under the threshold still trips a
``::warning::`` once the accumulated drift crosses it.  Drift checks are
warn-only — they never fail the job.

Suites may also carry a flat ``"counters"`` object (e.g. the achieved
per-SIMD-tier GB/s the traced kernel pass records as
``kernel_gemm_gbps_<tier>``).  Counters whose name contains ``gbps`` are
treated as higher-is-better throughputs and get the same warn-only drift
check against the history window's best value; other counters (like
``trace_off_overhead_frac``) are carried for the record but not judged.
"""

import json
import os
import re
import sys

THRESHOLD = float(os.environ.get("PERF_DIFF_THRESHOLD", "0.20"))
MIN_BASELINE_S = float(os.environ.get("PERF_DIFF_MIN_BASELINE_S", "200e-6"))
HISTORY_RUNS = int(os.environ.get("PERF_DIFF_HISTORY_RUNS", "10"))


def natural_key(path):
    """Sort key treating digit runs numerically (zero-padded), so
    ``bench-trajectory-12-2`` orders after ``bench-trajectory-12-1`` and
    after ``...-9-1``."""
    return re.sub(r"\d+", lambda m: m.group().zfill(12), path)


def bench_paths(root):
    """All BENCH_*.json under root, natural-sorted (artifact dirs nest)."""
    paths = []
    if not os.path.isdir(root):
        return paths
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in filenames:
            if fn.startswith("BENCH_") and fn.endswith(".json"):
                paths.append(os.path.join(dirpath, fn))
    return sorted(paths, key=natural_key)


def load_suites(root):
    """Map suite name -> ordered [(label, mean_s)] from BENCH_*.json under root.

    Files are visited in natural-sorted path order and later files replace
    earlier ones per suite — when a re-run leaves several
    ``bench-trajectory-<run>-<attempt>`` artifact directories side by side,
    the highest attempt's numbers win.
    """
    suites = {}
    for path in bench_paths(root):
        try:
            with open(path) as f:
                doc = json.load(f)
            rows = [(r["label"], float(r["mean_s"])) for r in doc["rows"]]
            suites[doc["suite"]] = rows
        except (OSError, ValueError, KeyError) as e:
            print(f"::warning::perf_diff: skipping unreadable {path}: {e}")
    return suites


def load_counters(root):
    """Map suite name -> {counter: value} from the optional per-suite
    ``"counters"`` object; suites without one map to ``{}``.  Same
    highest-attempt-wins ordering as ``load_suites``."""
    counters = {}
    for path in bench_paths(root):
        try:
            with open(path) as f:
                doc = json.load(f)
            counters[doc["suite"]] = {
                name: float(v) for name, v in doc.get("counters", {}).items()
            }
        except (OSError, ValueError, KeyError, TypeError, AttributeError) as e:
            print(f"::warning::perf_diff: skipping unreadable {path}: {e}")
    return counters


def recent_run_dirs(root):
    """The last ``HISTORY_RUNS`` run subdirectories of the history tree,
    oldest-to-newest (natural-sorted, so ``runs/12-1`` is newer than
    ``runs/9-1``)."""
    if not os.path.isdir(root):
        return []
    run_dirs = sorted(
        (d for d in os.listdir(root) if os.path.isdir(os.path.join(root, d))),
        key=natural_key,
    )
    return [os.path.join(root, d) for d in run_dirs[-HISTORY_RUNS:]]


def load_history(root):
    """suite -> label -> [mean_s, ...] oldest-to-newest over the recent
    history window."""
    history = {}
    for run in recent_run_dirs(root):
        for suite, rows in load_suites(run).items():
            per_suite = history.setdefault(suite, {})
            for label, mean_s in rows:
                per_suite.setdefault(label, []).append(mean_s)
    return history


def load_counter_history(root):
    """suite -> counter -> [value, ...] oldest-to-newest over the recent
    history window."""
    history = {}
    for run in recent_run_dirs(root):
        for suite, vals in load_counters(run).items():
            per_suite = history.setdefault(suite, {})
            for name, v in vals.items():
                per_suite.setdefault(name, []).append(v)
    return history


def drift_report(history, current):
    """Warn (never fail) on rows whose current mean has drifted past the
    threshold over the *best* mean in the recent history window — the slow
    regressions single-run diffs can't see.  Returns the flagged rows."""
    drifted = []
    for suite, rows in sorted(current.items()):
        hist = history.get(suite, {})
        for label, mean_s in rows:
            means = [m for m in hist.get(label, []) if m > 0.0]
            if len(means) < 2:
                continue  # no window to drift across
            best = min(means)
            if best < MIN_BASELINE_S:
                continue  # noise floor: same guard as the single-run gate
            ratio = mean_s / best
            if ratio > 1.0 + THRESHOLD:
                print(
                    f"::warning::perf drift over last {len(means)} runs: "
                    f"{suite}/{label}: best {best * 1e3:.3f} ms -> "
                    f"{mean_s * 1e3:.3f} ms ({ratio:.2f}x)"
                )
                drifted.append(f"{suite}/{label}")
    if drifted:
        print(f"perf_diff: {len(drifted)} slow drift(s) flagged (warn-only)")
    else:
        print("perf_diff: no slow drifts against the history window")
    return drifted


def counter_drift_report(history, current):
    """Warn (never fail) on higher-is-better throughput counters —
    ``gbps``-named values like the per-tier achieved GB/s — that have
    dropped more than the threshold below the history window's best.
    Returns the flagged counters."""
    flagged = []
    for suite, vals in sorted(current.items()):
        hist = history.get(suite, {})
        for name, value in sorted(vals.items()):
            if "gbps" not in name:
                continue  # not a judged throughput (e.g. overhead fractions)
            past = [v for v in hist.get(name, []) if v > 0.0]
            if len(past) < 2 or value <= 0.0:
                continue  # no window to drift across
            best = max(past)
            if value * (1.0 + THRESHOLD) < best:
                print(
                    f"::warning::throughput drift over last {len(past)} runs: "
                    f"{suite}/{name}: best {best:.2f} -> {value:.2f} "
                    f"({value / best:.2f}x)"
                )
                flagged.append(f"{suite}/{name}")
    if flagged:
        print(f"perf_diff: {len(flagged)} throughput drift(s) flagged (warn-only)")
    return flagged


USAGE = "usage: perf_diff.py <baseline-dir> <current-dir> [--history <dir>]"


def main(argv):
    args = list(argv[1:])
    history_dir = None
    if "--history" in args:
        i = args.index("--history")
        if i + 1 >= len(args):
            print(USAGE, file=sys.stderr)
            return 2
        history_dir = args[i + 1]
        del args[i : i + 2]
    if len(args) != 2:
        print(USAGE, file=sys.stderr)
        return 2
    baseline = load_suites(args[0])
    current = load_suites(args[1])
    if not current:
        print(f"::error::perf_diff: no BENCH_*.json found under {args[1]}")
        return 1
    if history_dir is not None:
        drift_report(load_history(history_dir), current)
        counter_drift_report(load_counter_history(history_dir), load_counters(args[1]))
    if not baseline:
        print("perf_diff: no baseline trajectories (first run?); nothing to compare")
        return 0

    failures = []
    for suite, rows in sorted(current.items()):
        base_rows = dict(baseline.get(suite, []))
        if not base_rows:
            print(f"perf_diff: suite {suite!r} has no baseline; skipping")
            continue
        headline = rows[0][0] if rows else None
        for label, mean_s in rows:
            if label not in base_rows:
                print(f"perf_diff: {suite}/{label!r} is new; no baseline")
                continue
            base = base_rows[label]
            if base <= 0.0:
                continue
            ratio = mean_s / base
            line = (
                f"{suite}/{label}: {base * 1e3:.3f} ms -> {mean_s * 1e3:.3f} ms "
                f"({ratio:.2f}x)"
            )
            if ratio <= 1.0 + THRESHOLD:
                print(f"perf_diff: ok {line}")
                continue
            gated = label == headline and base >= MIN_BASELINE_S
            if gated:
                print(f"::error::perf regression (headline): {line}")
                failures.append(f"{suite}/{label}")
            else:
                why = "sub-noise-floor baseline" if base < MIN_BASELINE_S else "non-headline"
                print(f"::warning::perf regression ({why}): {line}")

    if failures:
        print(
            f"perf_diff: {len(failures)} headline regression(s) past "
            f"{THRESHOLD:.0%}: {', '.join(failures)}"
        )
        return 1
    print("perf_diff: no headline regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
